"""Zero-execution semantic checks over every op x profile x workload.

The paper's analytical methodology works because plan and space
invariants are knowable *without running a kernel*: the
:class:`~repro.kernels.blocks.plan.StagePlan` is a pure function of
(workload, config, profile).  This module exploits that to verify, for
every ``known_ops()`` op under every registered
:class:`~repro.hw.profiles.HardwareProfile`:

  * **plan soundness** — :meth:`StagePlan.check` per valid config (stage
    radix product == tile, positive grids/blocks, per-launch VMEM within
    the physical pool, scratch holds its BlockSpec block, pass count ==
    launch count + the chain's XLA passes);
  * **model agreement** — ``core.analytical.resources()`` reports the
    same pass count / VMEM / grid the plan carries, and every
    ``RESOURCE_KEYS`` quantity is present and finite;
  * **feasibility** — each valid space contains at least one config whose
    plan fits ``vmem_budget`` (the tuner always has a lawful choice;
    over-budget candidates are allowed — they are the analytical tier-0
    stratum — but an all-over-budget space would force one);
  * **dead knobs** — a knob is dead when, aggregated over the whole
    checked workload grid, varying it never changes the launch list, the
    noise-free modeled cost, or the analytical guideline key.  A dead
    knob multiplies sweep cost and injects duplicate-label noise into the
    ML dataset for nothing (PR 5 pruned exactly such an ``unroll`` from
    the linrec space; the detector re-discovers that class of bug).

Workloads come from the ML suite grid (train + holdout sizes per op) —
the same sizes every sweep, dataset build, and CI evaluation uses.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.core.analytical import RESOURCE_KEYS, score
from repro.core.objective import CostModelObjective
from repro.core.space import SearchSpace, Workload, build_space
from repro.hw.profiles import get_profile, profiles
from repro.kernels.blocks.plan import plan_for
from repro.tuning.registry import known_ops
from repro.tuning.sweep import config_key


def suite_grid(op: str) -> List[Workload]:
    """The canonical per-op workload grid (every suite variant x size)."""
    from repro.tuning.ml.dataset import SUITE, suite_workloads
    if op not in SUITE:
        return []
    return suite_workloads("train", ops=[op]) \
        + suite_workloads("holdout", ops=[op])


def _finite(x) -> bool:
    return x == x and x not in (float("inf"), float("-inf"))


def check_space(space: SearchSpace) -> List[Finding]:
    """Plan soundness + model agreement + feasibility for one space."""
    wl, spec = space.workload, space.spec
    where = f"{spec.name}/{wl.key}"
    cands = space.enumerate_valid()
    findings: List[Finding] = []
    if not cands:
        return [Finding(rule="invariant.empty-space", path=where,
                        message="valid space is empty: no tuner can answer")]
    feasible = False
    for cfg in cands:
        plan = plan_for(wl, cfg, profile=spec)
        for violation in plan.check(spec):
            findings.append(Finding(
                rule="invariant.plan", path=where,
                message=f"config {config_key(cfg)}: {violation}"))
        res = plan.resources()
        for key in RESOURCE_KEYS:
            if key not in res or not _finite(res[key]):
                findings.append(Finding(
                    rule="invariant.resources", path=where,
                    message=f"config {config_key(cfg)}: resources[{key!r}] "
                            f"missing or non-finite "
                            f"(got {res.get(key)!r})"))
        if res.get("passes") != float(plan.passes) \
                or res.get("vmem") != float(plan.vmem_bytes) \
                or res.get("grid") != float(plan.grid_size):
            findings.append(Finding(
                rule="invariant.resources", path=where,
                message=f"config {config_key(cfg)}: resources() disagrees "
                        f"with the plan (passes {res.get('passes')} vs "
                        f"{plan.passes}, vmem {res.get('vmem')} vs "
                        f"{plan.vmem_bytes}, grid {res.get('grid')} vs "
                        f"{plan.grid_size})"))
        if plan.vmem_bytes <= spec.vmem_budget:
            feasible = True
    if not feasible:
        findings.append(Finding(
            rule="invariant.no-feasible-config", path=where,
            message=f"every valid config exceeds vmem_budget "
                    f"{spec.vmem_budget}: the whole space is analytical "
                    f"tier 0"))
    return findings


# -- dead knobs -------------------------------------------------------------

def _signatures(space: SearchSpace) -> List[Tuple]:
    """Per-candidate decision signature: everything any tuner can see.

    (launch list, chain pass accounting, noise-free modeled cost,
    analytical guideline key) — a knob that never moves any component can
    never change any methodology's decision, online or offline.  The pass
    accounting (``passes``/``xla_passes``) covers chain knobs like
    ``fuse`` whose effect can be to *relabel* a launch list (fold an XLA
    link into a kernel) without changing the Pallas launches themselves.
    """
    spec = space.spec
    obj = CostModelObjective(spec, noise=0.0)
    cands = space.enumerate_valid()
    costs = obj.batch_eval(space, cands, assume_valid=True)
    sigs: List[Tuple] = []
    for cfg, cost in zip(cands, costs):
        plan = plan_for(space.workload, cfg, profile=spec)
        key = score(space, cfg, res=plan.resources()).key()
        sigs.append((tuple(plan.launches), plan.passes, plan.xla_passes,
                     float(cost), key))
    return sigs


def find_dead_knobs(spaces: Sequence[SearchSpace]) -> List[str]:
    """Knobs dead across ALL given spaces (aggregate, not per-workload).

    For each space, candidates are grouped by the values of every *other*
    knob; the knob is live in that space when some group shows different
    signatures across the knob's values.  A knob legitimately inert at
    one size (e.g. ``unroll`` below the ILP knee) must be live *somewhere*
    on the grid; a knob live nowhere is dead.
    """
    alive: Dict[str, bool] = {}
    for space in spaces:
        cands = space.enumerate_valid()
        if not cands:
            continue
        sigs = _signatures(space)
        for ps in space.params:
            name = ps.name
            if len(ps.domain) < 2 or alive.get(name):
                continue
            groups: Dict[Tuple, List[Tuple]] = {}
            for cfg, sig in zip(cands, sigs):
                ctx = tuple(sorted((k, v) for k, v in cfg.items()
                                   if k != name))
                groups.setdefault(ctx, []).append((cfg.get(name), sig))
            for group in groups.values():
                if len({v for v, _ in group}) > 1:
                    alive.setdefault(name, False)
                    if len({s for _, s in group}) > 1:
                        alive[name] = True
                        break
    return sorted(name for name, live in alive.items() if not live)


def check_dead_knobs(op: str, spaces: Sequence[SearchSpace]
                     ) -> List[Finding]:
    """Findings for knobs dead across the whole grid of one op."""
    return [Finding(
        rule="invariant.dead-knob", path=op,
        message=f"knob {name!r} never changes the launch list, the "
                f"modeled cost, or the analytical rank anywhere on the "
                f"suite grid — prune it from the space (it doubles sweep "
                f"cost and duplicates ML labels for nothing)")
        for name in find_dead_knobs(spaces)]


# -- top-level runner -------------------------------------------------------

def check_invariants(ops: Optional[Iterable[str]] = None,
                     profile_names: Optional[Iterable[str]] = None,
                     max_sizes: Optional[int] = None) -> List[Finding]:
    """Run every semantic check over ops x profiles x the suite grid.

    ``max_sizes`` truncates the per-(op, variant) size list — used by
    fast test paths; the dead-knob aggregation always sees whatever grid
    the invariant sweep saw, so a truncated grid may over-report dead
    knobs (full-grid runs are the authority, and what CI gates on).
    """
    findings: List[Finding] = []
    op_list = list(ops) if ops is not None else known_ops()
    prof_list = list(profile_names) if profile_names is not None \
        else profiles()
    for op in op_list:
        grid = suite_grid(op)
        if max_sizes is not None:
            seen: Dict[str, int] = {}
            trimmed = []
            for wl in grid:
                seen[wl.variant] = seen.get(wl.variant, 0) + 1
                if seen[wl.variant] <= max_sizes:
                    trimmed.append(wl)
            grid = trimmed
        op_spaces: List[SearchSpace] = []
        for pname in prof_list:
            prof = get_profile(pname)
            for wl in grid:
                space = build_space(wl, prof)
                findings.extend(check_space(space))
                op_spaces.append(space)
        findings.extend(check_dead_knobs(op, op_spaces))
    return findings
