"""Beyond-paper: the paper's tuning methodologies applied to DISTRIBUTED
configuration (sharding strategy, remat, microbatching) with compiled
roofline terms as the objective.

The paper tunes (S, P, L, r, shuffle) per kernel against wall-clock; at pod
scale the analogous knobs are per-(arch x shape) distribution choices and
the "device" is the XLA-compiled module. The objective is the dominant
roofline term from launch/roofline.py — exactly the quantity §Perf
hillclimbs — so the same AnalyticalTuner/BayesianTuner/ExhaustiveSearch
machinery drives the search.

Space (discrete, enumerable — like the paper's):
    activation_strategy: tp | sp             (residual sharding)
    micro_steps:         1 | 2 | 4 | 8       (gradient accumulation)
    remat:               full | none
    moe_group_size:      512 | 1024 | 2048   (MoE cells only)

The objective evaluates lower+compile per candidate (minutes each — the
same order as the paper's 100-execution medians), so the BO search's
evaluation frugality matters here even more than on-kernel.
"""
from __future__ import annotations

from typing import Dict

from repro.core.bayesian import BayesianTuner, TuneResult
from repro.core.exhaustive import ExhaustiveSearch
from repro.core.objective import Measurement, Objective, PENALTY_TIME
from repro.core.space import Config, ParamSpec, SearchSpace, Workload
from repro.hw.profiles import TPU_V5E as V5E


def distributed_space(arch: str, shape: str, is_moe: bool = False,
                      is_train: bool = True) -> SearchSpace:
    wl = Workload(op="distributed", n=0, batch=0, variant=f"{arch}|{shape}")
    params = [
        ParamSpec("sp", (0, 1)),                       # activation_strategy
        ParamSpec("micro_steps", (1, 2, 4, 8) if is_train else (1,)),
        ParamSpec("remat", (0, 1) if is_train else (1,)),  # 1 = full
        ParamSpec("moe_group", (512, 1024, 2048) if is_moe else (1024,)),
    ]
    return SearchSpace(wl, params, constraints=())


HBM_BYTES = 16 * 2**30

# per-extra-micro-step dispatch + accumulation-barrier cost (the scan body
# is re-dispatched and the carry flushed once per micro step)
MICRO_STEP_SYNC_S = 20e-6


def micro_step_overhead_s(micro_steps: int, grad_bytes_per_dev: float,
                          spec=V5E) -> float:
    """Cost of gradient accumulation the compiled roofline cannot see.

    The trip-count-exact jaxpr roofline already counts the micro-step
    scan's compute and weight re-reads, but its fused-elementwise bytes
    model treats the f32 gradient-accumulator ``g_acc + g`` as free — in
    reality every extra micro step pays a full read-modify-write of the
    per-device gradient shard through HBM, plus a dispatch/sync.  Charging
    it here is what makes ``micro_steps`` a real trade-off (smaller
    activation footprint vs accumulation traffic) instead of a free knob.
    """
    extra = max(int(micro_steps), 1) - 1
    if extra == 0:
        return 0.0
    rmw = 2.0 * max(grad_bytes_per_dev, 0.0) / spec.hbm_bandwidth
    return extra * (rmw + MICRO_STEP_SYNC_S)


def step_time_from_record(rec: Dict, cfg: Config,
                          grad_bytes_per_dev: float = 0.0) -> float:
    """Full-step objective time for ``cfg`` given one roofline record."""
    return float(rec["step_time_bound_s"]) + micro_step_overhead_s(
        cfg.get("micro_steps", 1), grad_bytes_per_dev)


class CompiledRooflineObjective(Objective):
    """lower+compile the cell under the candidate distribution config and
    return the dominant roofline term (seconds); OOM (peak > HBM) and
    compile failures get the penalty clamp, exactly like the paper's
    invalid-configuration handling."""

    def __init__(self, multi_pod: bool = False, hbm_guard: bool = True):
        self.multi_pod = multi_pod
        self.hbm_guard = hbm_guard

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        import dataclasses as dc

        from repro.configs.base import get_arch
        from repro.launch.roofline import analyze_cell
        from repro.train.step import TrainHParams

        arch, shape = space.workload.variant.split("|")
        base = get_arch(arch)
        arch_cfg = dc.replace(
            base,
            activation_strategy="sp" if cfg["sp"] else "tp",
            remat="full" if cfg["remat"] else "none",
            moe_group_size=cfg["moe_group"],
        )
        hp = TrainHParams(micro_steps=cfg["micro_steps"])
        try:
            rec = analyze_cell(arch, shape, multi_pod=self.multi_pod,
                               arch_cfg=arch_cfg, hp=hp)
        except Exception:
            return Measurement(PENALTY_TIME, False)
        if rec.get("status") != "ok":
            return Measurement(PENALTY_TIME, False)
        peak = rec["per_device"]["peak_bytes"]
        if self.hbm_guard and peak > HBM_BYTES:
            # infeasible on real hardware -> penalty, scaled so "close"
            # configs still order (helps the surrogate learn the cliff)
            return Measurement(PENALTY_TIME * (peak / HBM_BYTES), False,
                               meta={"peak_bytes": peak})
        from repro.launch.params import total_param_count
        chips = max(int(rec.get("chips", 1)), 1)
        grad_bytes_dev = 4.0 * total_param_count(arch_cfg) / chips
        t = step_time_from_record(rec, cfg, grad_bytes_dev)
        return Measurement(
            t, True,
            meta={"peak_bytes": peak, **rec["roofline"],
                  "dominant": rec["dominant"],
                  "micro_overhead_s": t - rec["step_time_bound_s"]})


def tune_distributed(arch: str, shape: str, method: str = "bayesian",
                     multi_pod: bool = False, max_evals: int = 12,
                     seed: int = 0) -> TuneResult:
    from repro.configs.base import get_arch

    base = get_arch(arch)
    space = distributed_space(arch, shape, is_moe=base.family == "moe",
                              is_train=shape.startswith("train"))
    objective = CompiledRooflineObjective(multi_pod=multi_pod)
    if method == "bayesian":
        return BayesianTuner(max_evals=max_evals, seed=seed,
                             n_init=3).tune(space, objective)
    if method == "exhaustive":
        return ExhaustiveSearch().tune(space, objective)
    raise ValueError(method)
