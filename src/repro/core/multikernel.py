"""Large problem sizes: multi-pass (multi-kernel) tuning (paper §IV-C).

When N exceeds the on-chip working set (VMEM tile), the operation decomposes
into m passes with HBM roundtrips between them. The paper's analytical rule:
minimize m = ceil(n / s) (N = r^n, S = r^s), then tune each pass with the
small/medium-size guideline. The ML route simply widens the space — per-pass
tuples are interdependent, but the surrogate treats the whole vector as one
black-box point.

We reproduce both: `analytical_multipass` applies the minimize-m rule with
the per-pass analytical guideline; `ml_multipass_space` builds the joint
space over interdependent per-pass parameters for the BO search.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.core.analytical import AnalyticalTuner
from repro.core.objective import Measurement, Objective, PENALTY_TIME, CostModelObjective
from repro.core.space import Config, SearchSpace, Workload, build_space
from repro.hw.profiles import active_profile, dtype_bytes


def num_passes(n: int, tile_n: int, radix: int = 2) -> int:
    """m = ceil(log_r N / log_r S) — radix cancels; depends only on n, s."""
    return max(1, math.ceil(math.log2(max(n, 2)) / math.log2(max(tile_n, 2))))


def max_resident_tile(wl: Workload, spec=None) -> int:
    """Largest power-of-two tile whose double-buffered footprint fits VMEM
    with at least one problem row per program (delegates to the StagePlan
    layer, which uses the same boundary to decide fused vs multi-pass)."""
    from repro.kernels.blocks.plan import resident_tile_cap

    return resident_tile_cap(wl, spec)


@dataclasses.dataclass
class MultiPassPlan:
    workload: Workload
    passes: List[Config]          # one tuned config per pass
    tile_n: int
    m: int
    method: str

    def total_time(self, objective: Objective) -> float:
        t = 0.0
        for cfg in self.passes:
            sub = Workload(op=self.workload.op if self.workload.op != "large_fft" else "fft",
                           n=cfg["tile_n"], batch=self.workload.batch * (self.workload.n // cfg["tile_n"]),
                           dtype=self.workload.dtype, variant=self.workload.variant)
            space = build_space(sub)
            m = objective(space, cfg)
            t += m.time_s if m.valid else PENALTY_TIME
        return t


def analytical_multipass(wl: Workload, spec=None) -> MultiPassPlan:
    """Paper rule: pick the largest S (minimize m), then per-pass guideline."""
    tile = max_resident_tile(wl, spec)
    m = num_passes(wl.n, tile)
    tuner = AnalyticalTuner()
    passes: List[Config] = []
    for _ in range(m):
        sub = Workload(op="fft" if wl.op in ("fft", "large_fft") else wl.op,
                       n=tile, batch=max(wl.batch, 1) * (wl.n // tile),
                       dtype=wl.dtype, variant=wl.variant)
        cfg = tuner.suggest(build_space(sub))
        cfg = dict(cfg)
        cfg["tile_n"] = tile
        passes.append(cfg)
    return MultiPassPlan(wl, passes, tile, m, "analytical")


class MultiPassObjective(Objective):
    """Joint objective for the ML search over the multi-pass space.

    A candidate assigns one (tile_n, radix, rows, unroll) tuple *per pass*
    via suffixed parameter names; passes are summed. Interdependency: the
    tile of pass i fixes the batch reshaping of pass i+1 (modeled through
    the per-pass workload construction), and a mismatched tile chain adds a
    transpose penalty — the "intricacies transparent to the black box".
    """

    def __init__(self, inner: Objective = None):
        self.inner = inner or CostModelObjective()

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        wl = space.workload
        m = num_passes(wl.n, cfg["tile_n"])
        total = 0.0
        meta: Dict[str, float] = {"m": m}
        elems_left = wl.n
        for i in range(m):
            tile = min(cfg["tile_n"], elems_left)
            sub = Workload(op="fft" if wl.op in ("fft", "large_fft") else wl.op,
                           n=tile, batch=max(wl.batch, 1) * (wl.n // tile),
                           dtype=wl.dtype, variant=wl.variant)
            sub_cfg = dict(cfg)
            sub_cfg["tile_n"] = tile
            sub_space = build_space(sub)
            if not sub_space.is_valid(sub_cfg):
                return Measurement(PENALTY_TIME, False)
            meas = self.inner(sub_space, sub_cfg)
            if not meas.valid:
                return Measurement(PENALTY_TIME, False)
            total += meas.time_s
            elems_left = max(elems_left // tile, 1)
        # inter-pass HBM transpose roundtrip (billed at the device the inner
        # objective models; active profile when the inner carries no spec)
        spec = getattr(self.inner, "spec", None)
        if spec is None:
            spec = active_profile()
        eb = dtype_bytes(wl.dtype) * (2 if wl.op in ("fft", "large_fft") else 1)
        roundtrip = 2.0 * wl.n * max(wl.batch, 1) * eb / spec.hbm_bandwidth
        total += (m - 1) * roundtrip
        return Measurement(total, True, meta)
