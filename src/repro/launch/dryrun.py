import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 host placeholders.

For each cell we jax.jit the step with explicit in/out shardings, .lower()
it on ShapeDtypeStruct inputs, .compile(), and record memory_analysis() +
cost_analysis() + the collective bytes parsed from the optimized HLO —
the inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, SHAPES, all_archs,
                                get_arch, shape_applicable)
from repro.distributed.sharding import (ShardingDecisions, batch_specs,
                                        cache_specs, param_specs,
                                        train_state_specs)
from repro.launch.inputs import (abstract_train_state, decode_input_specs,
                                 prefill_input_specs, train_input_specs)
from repro.launch.mesh import batch_axes as mesh_batch_axes, make_production_mesh
from repro.models.model import build_model
from repro.train.step import (TrainHParams, make_decode_step,
                              make_prefill_step, make_train_step)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# per-arch training hyperparameter overrides: gradient accumulation keeps
# the biggest models' activation working set inside v5e HBM (a standard
# production lever; recorded per cell in EXPERIMENTS.md)
_HP_OVERRIDES = {
    "llama-3.2-vision-90b": TrainHParams(micro_steps=4),
    "granite-34b": TrainHParams(micro_steps=2),
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               hp: Optional[TrainHParams] = None,
               arch_cfg: Optional[ModelConfig] = None,
               return_artifacts: bool = False) -> Dict[str, Any]:
    """Lower+compile one cell; returns the §Dry-run record."""
    cfg = arch_cfg or get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "mesh": "2x16x16" if multi_pod else "16x16", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = mesh_batch_axes(mesh)
    if cfg.pure_dp:
        baxes = baxes + ("model",)
    bshards = 1
    for a in baxes:
        bshards *= mesh.shape[a]
    cfg = dataclasses.replace(
        cfg, model_axis_size=0 if cfg.pure_dp else mesh.shape["model"],
        batch_axes=baxes, batch_shards=bshards)
    model = build_model(cfg)
    hp = hp or _HP_OVERRIDES.get(arch, TrainHParams())
    decisions = ShardingDecisions()
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            state = abstract_train_state(model, hp)
            sspecs = train_state_specs(state, mesh, decisions,
                                       pure_dp=cfg.pure_dp)
            batch = train_input_specs(cfg, shape)
            bspecs = batch_specs(batch, mesh, axes=cfg.batch_axes)
            step = make_train_step(model, hp)
            jitted = jax.jit(step,
                             in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs)),
                             out_shardings=(_ns(mesh, sspecs), None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            params = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            pspecs = param_specs(params, mesh, decisions)
            batch = prefill_input_specs(cfg, shape)
            bspecs = batch_specs(batch, mesh)
            step = make_prefill_step(model)
            jitted = jax.jit(step,
                             in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
                             out_shardings=None)
            lowered = jitted.lower(params, batch)
        else:  # decode
            params = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            pspecs = param_specs(params, mesh, decisions)
            inputs, cache = decode_input_specs(cfg, shape)
            cspecs = cache_specs(cache, mesh)
            ispecs = batch_specs(inputs, mesh)
            step = make_decode_step(model)
            in_sh = [_ns(mesh, pspecs), _ns(mesh, ispecs["token"]),
                     _ns(mesh, cspecs), _ns(mesh, ispecs["pos"])]
            args = (params, inputs["token"], cache, inputs["pos"])
            if "memory" in inputs:
                in_sh.append(_ns(mesh, ispecs["memory"]))
                args = args + (inputs["memory"],)
            jitted = jax.jit(
                step,
                in_shardings=tuple(in_sh),
                out_shardings=(None, _ns(mesh, cspecs)),
                donate_argnums=(2,))
            lowered = jitted.lower(*args)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        jaxpr_cost = None
        if return_artifacts:
            # trip-count-exact analytic flops/bytes (XLA:CPU cost_analysis
            # counts while bodies once — see launch/jaxpr_cost.py)
            from repro.launch.jaxpr_cost import analyze_jaxpr
            if shape.kind == "train":
                jaxpr_cost = analyze_jaxpr(step, state, batch)
            elif shape.kind == "prefill":
                jaxpr_cost = analyze_jaxpr(step, params, batch)
            else:
                jaxpr_cost = analyze_jaxpr(step, *args)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "sharding_fallbacks": decisions.fallbacks,
    }
    if return_artifacts:
        record["_lowered"] = lowered
        record["_compiled"] = compiled
        record["jaxpr_cost"] = jaxpr_cost
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in all_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
            try:
                rec = lower_cell(arch, shape, multi_pod=mp)
            except Exception as e:  # a failure here is a bug in the system
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            results.append(rec)
            status = rec["status"]
            extra = (f" peak={rec['per_device']['peak_bytes']/2**30:.2f}GiB"
                     f" flops={rec['flops']:.3e}"
                     if status == "ok" else rec.get("reason",
                                                    rec.get("error", "")))
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
            path = os.path.join(args.out,
                                f"{arch}_{shape}_{rec['mesh']}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = len(results) - n_ok - n_skip
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
