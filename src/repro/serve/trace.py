"""Synthetic multi-tenant serving traces (seeded, reproducible).

The serving benchmark's load generator: a handful of tenant classes with
different prompt/output length profiles and Poisson arrival rates, drawn
from one seeded ``numpy`` Generator so the same seed always produces the
same request stream — the determinism contract every gated benchmark in
this repo follows.

A trace is a flat list of :class:`TraceRequest` ordered by arrival tick.
``benchmarks/bench_serving.py`` replays the same trace through the
optimized :class:`~repro.serve.engine.ServeEngine` and the
:class:`~repro.serve.reference.ReferenceEngine` and gates the tokens/sec
ratio; ``repro.launch.serve --trace-tenants`` drives live runs with it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant class: arrival rate + prompt/output length ranges."""

    name: str
    rate: float                    # mean arrivals per tick (Poisson)
    prompt_len: Tuple[int, int]    # inclusive [lo, hi]
    max_new: Tuple[int, int]       # inclusive [lo, hi]

    def __post_init__(self):
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")
        for lo, hi in (self.prompt_len, self.max_new):
            if not 1 <= lo <= hi:
                raise ValueError(
                    f"bad range [{lo}, {hi}] for tenant {self.name!r}")


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One request of a synthetic trace, in arrival order."""

    tenant: str
    arrival: int                   # tick the request arrives at
    prompt: np.ndarray             # (len,) int32
    max_new_tokens: int


def default_tenants() -> List[TenantSpec]:
    """The benchmark's mixed workload: chatty interactive traffic, a
    prompt-heavy analytics tenant, and a trickle of background jobs."""
    return [
        TenantSpec("interactive", rate=0.6, prompt_len=(6, 16),
                   max_new=(6, 12)),
        TenantSpec("analytics", rate=0.4, prompt_len=(40, 64),
                   max_new=(4, 8)),
        TenantSpec("background", rate=0.2, prompt_len=(20, 32),
                   max_new=(2, 4)),
    ]


def synthetic_trace(tenants: Sequence[TenantSpec], *, horizon: int,
                    vocab: int, seed: int = 0) -> List[TraceRequest]:
    """Draw a multi-tenant request stream over ``horizon`` arrival ticks.

    Per tick, each tenant contributes ``Poisson(rate)`` requests with
    prompt tokens uniform over ``[0, vocab)`` and lengths uniform over
    the tenant's ranges.  Fully determined by ``seed``.
    """
    if horizon < 1:
        raise ValueError(f"horizon must be >= 1, got {horizon}")
    if vocab < 1:
        raise ValueError(f"vocab must be >= 1, got {vocab}")
    rng = np.random.default_rng(seed)
    out: List[TraceRequest] = []
    for tick in range(horizon):
        for spec in tenants:
            for _ in range(int(rng.poisson(spec.rate))):
                plen = int(rng.integers(spec.prompt_len[0],
                                        spec.prompt_len[1] + 1))
                new = int(rng.integers(spec.max_new[0],
                                       spec.max_new[1] + 1))
                prompt = rng.integers(0, vocab, size=plen,
                                      dtype=np.int64).astype(np.int32)
                out.append(TraceRequest(spec.name, tick, prompt, new))
    return out


def trace_summary(trace: Sequence[TraceRequest]) -> dict:
    """Aggregate shape of a trace (benchmark reporting rows)."""
    if not trace:
        return {"requests": 0, "prompt_tokens": 0, "decode_tokens": 0}
    return {
        "requests": len(trace),
        "prompt_tokens": int(sum(len(r.prompt) for r in trace)),
        "decode_tokens": int(sum(r.max_new_tokens for r in trace)),
        "tenants": sorted({r.tenant for r in trace}),
    }
