"""Tuner facade + persistent TuningDB (offline -> online handoff).

The paper's deployment story: offline, run the expensive searches and store
the winning configuration per (op, variant, N, batch, dtype, platform);
online, kernels look their configuration up, and on a miss the analytical
model answers immediately with zero evaluations (its headline advantage).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Callable, Dict, Optional

from repro.core.analytical import AnalyticalTuner
from repro.core.bayesian import BayesianTuner, TuneResult
from repro.core.exhaustive import ExhaustiveSearch, RandomSearch
from repro.core.objective import Objective, TPUCostModelObjective, CachedObjective
from repro.core.space import Config, SearchSpace, Workload, build_space

DEFAULT_DB_PATH = os.environ.get(
    "REPRO_TUNING_DB", os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                    "artifacts", "tuning_db.json"))


class TuningDB:
    """JSON-backed config store; thread-safe; content-addressed by workload key."""

    def __init__(self, path: Optional[str] = None, platform: str = "tpu_v5e"):
        self.path = os.path.abspath(path or DEFAULT_DB_PATH)
        self.platform = platform
        self._lock = threading.Lock()
        self._data: Dict[str, Dict] = {}
        self._loaded = False

    def _load(self) -> None:
        if self._loaded:
            return
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    self._data = json.load(f)
            except (json.JSONDecodeError, OSError):
                self._data = {}
        self._loaded = True

    def _key(self, wl: Workload) -> str:
        return f"{self.platform}|{wl.key}"

    def lookup(self, wl: Workload) -> Optional[Config]:
        with self._lock:
            self._load()
            entry = self._data.get(self._key(wl))
            return dict(entry["config"]) if entry else None

    def store(self, wl: Workload, cfg: Config, time_s: float, method: str,
              evaluations: int = 0) -> None:
        with self._lock:
            self._load()
            self._data[self._key(wl)] = {
                "config": cfg, "time_s": time_s, "method": method,
                "evaluations": evaluations,
            }
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self._data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)

    def entries(self) -> Dict[str, Dict]:
        with self._lock:
            self._load()
            return dict(self._data)


_GLOBAL_DB: Optional[TuningDB] = None
_ANALYTICAL = AnalyticalTuner()


def global_db() -> TuningDB:
    global _GLOBAL_DB
    if _GLOBAL_DB is None:
        _GLOBAL_DB = TuningDB()
    return _GLOBAL_DB


def get_config(wl: Workload, db: Optional[TuningDB] = None) -> Config:
    """Online entry point used by every kernel launcher.

    DB hit -> stored (offline-tuned) config; miss -> analytical model, which
    needs no evaluations (paper's recommendation for online tuning).
    """
    db = db or global_db()
    cfg = db.lookup(wl)
    if cfg is not None:
        return cfg
    return _ANALYTICAL.suggest(build_space(wl))


def tune_offline(wl: Workload, method: str = "bayesian",
                 objective: Optional[Objective] = None,
                 db: Optional[TuningDB] = None, seed: int = 0,
                 max_evals: int = 64) -> TuneResult:
    """Offline tuning pass; persists the winner into the DB."""
    space = build_space(wl)
    objective = objective or TPUCostModelObjective()
    cached = CachedObjective(objective)
    if method == "bayesian":
        result = BayesianTuner(seed=seed, max_evals=max_evals).tune(space, cached)
    elif method == "exhaustive":
        result = ExhaustiveSearch().tune(space, cached)
    elif method == "random":
        result = RandomSearch(max_evals=max_evals, seed=seed).tune(space, cached)
    elif method == "analytical":
        cfg = _ANALYTICAL.suggest(space)
        m = cached(space, cfg)
        result = TuneResult(cfg, m.time_s, 0, [(cfg, m.time_s)], "analytical")
    else:
        raise ValueError(f"unknown tuning method {method!r}")
    (db or global_db()).store(wl, result.best_config, result.best_time,
                              method, result.evaluations)
    return result
