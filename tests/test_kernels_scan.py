"""Pallas scan kernels vs pure-jnp oracle: shape/dtype/radix sweeps +
hypothesis properties."""
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels.scan.kernel import scan_add_pallas, scan_linrec_pallas
from repro.kernels.scan.ops import linear_recurrence, prefix_sum
from repro.kernels.scan.ref import (scan_add_ref, scan_linrec_assoc_ref,
                                    scan_linrec_ref)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("batch,n,rows,tile,radix,unroll", [
    (8, 256, 4, 256, 2, 1),
    (8, 256, 8, 128, 4, 2),
    (16, 1024, 4, 256, 8, 1),     # multi-tile carry path
    (4, 512, 2, 512, 4, 4),
    (2, 128, 1, 128, 2, 1),
])
def test_scan_add_matches_oracle(batch, n, rows, tile, radix, unroll):
    x = jnp.asarray(RNG.normal(size=(batch, n)), jnp.float32)
    got = scan_add_pallas(x, rows_per_program=rows, tile_n=tile, radix=radix,
                          unroll=unroll, interpret=True)
    np.testing.assert_allclose(got, scan_add_ref(x), rtol=2e-5, atol=2e-4)


# dtype x odd/prime-shape coverage moved to the shared differential suite
# (tests/conftest.py KERNEL_CASES + test_kernels_differential.py)


@pytest.mark.parametrize("batch,n,rows,tile,radix", [
    (8, 256, 4, 256, 2),
    (8, 512, 8, 128, 4),          # multi-tile carry for linrec
    (4, 1024, 2, 1024, 8),
])
def test_scan_linrec_matches_sequential(batch, n, rows, tile, radix):
    a = jnp.asarray(RNG.uniform(0.8, 0.999, size=(batch, n)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(batch, n)), jnp.float32)
    got = scan_linrec_pallas(a, b, rows_per_program=rows, tile_n=tile,
                             radix=radix, interpret=True)
    np.testing.assert_allclose(got, scan_linrec_ref(a, b), rtol=2e-4,
                               atol=2e-4)


def test_ops_wrappers_consume_configs():
    x = jnp.asarray(RNG.normal(size=(4, 256)), jnp.float32)
    got = prefix_sum(x, config={"tile_n": 128, "rows_per_program": 2,
                                "radix": 4, "unroll": 1}, interpret=True)
    np.testing.assert_allclose(got, scan_add_ref(x), rtol=2e-5, atol=2e-4)
    # ref fallback path
    got2 = prefix_sum(x, use_pallas=False)
    np.testing.assert_allclose(got2, scan_add_ref(x), rtol=1e-6)


@given(st.integers(min_value=1, max_value=6), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_prefix_sum_linearity(log_n, seed):
    """scan(ax + by) == a scan(x) + b scan(y) (property of the monoid)."""
    n = 2 ** (log_n + 4)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(2, n)), jnp.float32)
    y = jnp.asarray(r.normal(size=(2, n)), jnp.float32)
    lhs = scan_add_pallas(2.0 * x + 3.0 * y, rows_per_program=2, tile_n=n,
                          radix=2, interpret=True)
    rhs = (2.0 * scan_add_pallas(x, rows_per_program=2, tile_n=n, radix=2,
                                 interpret=True)
           + 3.0 * scan_add_pallas(y, rows_per_program=2, tile_n=n, radix=2,
                                   interpret=True))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-3)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_linrec_matches_associative_formulation(seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.uniform(0.5, 1.0, size=(2, 128)), jnp.float32)
    b = jnp.asarray(r.normal(size=(2, 128)), jnp.float32)
    got = linear_recurrence(a, b, config={"tile_n": 128,
                                          "rows_per_program": 2, "radix": 2,
                                          "unroll": 1}, interpret=True)
    np.testing.assert_allclose(got, scan_linrec_assoc_ref(a, b), rtol=2e-4,
                               atol=2e-4)
