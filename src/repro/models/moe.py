"""Mixture-of-Experts block (grouped GShard top-k dispatch, EP-shardable).

Routing is computed per GROUP of `moe_group_size` tokens (GShard's S):
dispatch/combine tensors are (G, S, E, C) with the group dim inheriting the
data sharding and experts on "model" (EP). A flat (T, E, C) formulation is
quadratic in tokens (C ~ T/E) and measured 676 GiB/device on the train_4k
cells; grouping makes C ~ S/E and the whole object linear in T.

With tokens on ("pod","data") and experts on "model", XLA lowers the
dispatch einsums to all-to-alls (verified by the roofline parser).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _act, dense, init_dense, init_mlp, mlp


def padded_experts(cfg: ModelConfig) -> int:
    """Physical expert count, padded to a model-axis multiple so EP shards
    evenly (qwen2-moe: 60 -> 64 on a 16-way axis; pads never receive
    tokens — the router only emits real indices)."""
    e, m = cfg.n_experts, cfg.model_axis_size
    if m and e % m:
        return ((e + m - 1) // m) * m
    return e


def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    kr, ke, ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff_expert
    e = padded_experts(cfg)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": init_dense(kr, d, cfg.n_experts, dtype),
        "wi": (jax.random.normal(ke, (e, d, f), jnp.float32) * scale).astype(dtype),
        "wu": (jax.random.normal(jax.random.fold_in(ke, 1), (e, d, f),
                                 jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(jax.random.fold_in(ke, 2), (e, f, d),
                                 jnp.float32) / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks, d, cfg.d_ff_expert * cfg.n_shared_experts,
                               cfg.activation, dtype)
    return p


def _expert_constraint(t: jax.Array, cfg: ModelConfig, e_dim: int):
    """Shard the expert dim on "model" (EP) when divisible; group dim on the
    batch axes. UNCONSTRAINED elsewhere (see attention._score_constraint)."""
    if not cfg.batch_axes or not cfg.model_axis_size or (
            cfg.batch_shards and t.shape[0] % cfg.batch_shards):
        return t
    U = P.UNCONSTRAINED
    b = cfg.batch_axes if len(cfg.batch_axes) > 1 else cfg.batch_axes[0]
    e = t.shape[e_dim]
    axes = [U] * t.ndim
    axes[0] = b
    if e % cfg.model_axis_size == 0:
        axes[e_dim] = "model"
    return jax.lax.with_sharding_constraint(t, P(*axes))


def moe_block(p: Dict, x: jax.Array, cfg: ModelConfig, compute_dtype
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). x: (B, L, D)."""
    bsz, l, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    ep = padded_experts(cfg)          # physical (padded) expert-bank size

    # ---- grouping: (B, L, D) -> (G, S, D), G inherits batch sharding ----
    s = min(getattr(cfg, "moe_group_size", 1024) or 1024, l)
    while l % s:
        s //= 2
    s = max(s, 1)
    g = bsz * (l // s)
    xg = x.reshape(g, s, d)

    gate_logits = dense(p["router"], xg, jnp.float32)            # (G, S, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                   # (G, S, k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * mean_e(f_e * P_e), averaged over groups
    me = jnp.mean(probs, axis=1)                                 # (G, E)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e), axis=2), axis=1)
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    capacity = max(int(math.ceil(s * k / e * cfg.capacity_factor)), 1)

    # position of each (token, choice) in its expert queue, per group
    onehot_e = jax.nn.one_hot(gate_idx, ep, dtype=jnp.int32)     # (G, S, k, Ep)
    flat = onehot_e.reshape(g, s * k, ep)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (G, S*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, s, k)
    keep = pos < capacity

    disp_e = (onehot_e.astype(compute_dtype)
              * keep[..., None].astype(compute_dtype))           # (G, S, k, Ep)
    pos_c = jax.nn.one_hot(pos, capacity, dtype=compute_dtype)   # (G, S, k, C)
    dispatch = jnp.einsum("gske,gskc->gsec", disp_e, pos_c)      # (G, S, E, C)
    dispatch = _expert_constraint(dispatch, cfg, 2)
    combine_w = jnp.einsum("gsk,gske,gskc->gsec",
                           gate_w.astype(compute_dtype), disp_e, pos_c)

    expert_in = jnp.einsum("gsd,gsec->gecd", xg.astype(compute_dtype),
                           dispatch)                             # (G, E, C, D)
    expert_in = _expert_constraint(expert_in, cfg, 1)

    gih = _act(cfg.activation, jnp.einsum(
        "gecd,edf->gecf", expert_in, p["wi"].astype(compute_dtype)))
    u = jnp.einsum("gecd,edf->gecf", expert_in, p["wu"].astype(compute_dtype))
    expert_out = jnp.einsum("gecf,efd->gecd", gih * u,
                            p["wo"].astype(compute_dtype))       # (G, E, C, D)
    expert_out = _expert_constraint(expert_out, cfg, 1)

    out = jnp.einsum("gsec,gecd->gsd", combine_w, expert_out)
    out = out.reshape(bsz, l, d)

    if "shared" in p:
        out = out + mlp(p["shared"], x, cfg.activation, compute_dtype)
    return out.astype(x.dtype), aux.astype(jnp.float32)
