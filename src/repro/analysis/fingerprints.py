"""Version-drift fingerprints for the stack's persisted contracts.

Four contracts outlive any single process — ML feature columns, journal
headers, DB entry keys/fields, and the serialized Measurement layout.
Each carries a ``*_VERSION`` constant whose bump invalidates stale
artifacts *loudly*; what nothing enforced until now is the bump itself:
edit ``FEATURE_NAMES`` without touching ``FEATURE_VERSION`` and every
trained forest silently mis-predicts, reshape the journal header and
every sweep resumes against garbage.

This module pins a content hash of each contract next to its version in
``tests/fixtures/analysis_fingerprints.json``:

  * hash changed, version unchanged  -> lint error ("bump the version");
  * version changed (fixture stale)  -> lint error ("refresh the fixture
    with ``tune.py lint --write-fingerprints`` in the same PR");
  * both match                       -> silence.

Adding a contract: extend :data:`CONTRACTS` with ``name -> provider``
where the provider returns ``(version, payload)`` — the payload is any
JSON-serializable description of the layout — then refresh the fixture.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

FINGERPRINT_FIXTURE = os.path.join("tests", "fixtures",
                                   "analysis_fingerprints.json")


def _feature_columns() -> Tuple[int, object]:
    from repro.tuning.ml.features import FEATURE_NAMES, FEATURE_VERSION
    return FEATURE_VERSION, list(FEATURE_NAMES)


def _journal_header() -> Tuple[int, object]:
    from repro.tuning.sweep import HEADER_FIELDS, JOURNAL_VERSION
    return JOURNAL_VERSION, list(HEADER_FIELDS)


def _db_entry() -> Tuple[int, object]:
    from repro.tuning.db import ENTRY_FIELDS, KEY_FORMATS, SCHEMA_VERSION
    return SCHEMA_VERSION, {"key_formats": list(KEY_FORMATS),
                            "entry_fields": list(ENTRY_FIELDS)}


def _measurement() -> Tuple[int, object]:
    from repro.core.objective import MEASUREMENT_FIELDS, MEASUREMENT_VERSION
    return MEASUREMENT_VERSION, list(MEASUREMENT_FIELDS)


CONTRACTS: Dict[str, Callable[[], Tuple[int, object]]] = {
    "feature_columns": _feature_columns,
    "journal_header": _journal_header,
    "db_entry": _db_entry,
    "measurement": _measurement,
}


def _digest(payload: object) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def current_fingerprints() -> Dict[str, Dict]:
    """``{contract: {"version": int, "hash": sha256}}`` for the live code."""
    out: Dict[str, Dict] = {}
    for name, provider in sorted(CONTRACTS.items()):
        version, payload = provider()
        out[name] = {"version": int(version), "hash": _digest(payload)}
    return out


def default_fixture_path(root: Optional[str] = None) -> str:
    """``tests/fixtures/analysis_fingerprints.json`` under the repo root."""
    if root is None:
        import repro
        # src/repro/__init__.py -> src/repro -> src -> repo root
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(repro.__file__))))
    return os.path.join(root, FINGERPRINT_FIXTURE)


def write_fingerprints(path: str) -> Dict[str, Dict]:
    """Refresh the pinned fixture from the live code (returns what it wrote).

    Only legitimate when every changed contract also bumped its version —
    which is exactly what the next lint run verifies against the new pin.
    """
    pins = current_fingerprints()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(pins, f, indent=1, sort_keys=True)
        f.write("\n")
    return pins


def check_fingerprints(path: str) -> List[Finding]:
    """Compare the live contracts against the pinned fixture."""
    loc = os.path.relpath(path) if os.path.isabs(path) else path
    if not os.path.exists(path):
        return [Finding(rule="fingerprint.missing-fixture", path=loc,
                        message="pinned fingerprint fixture not found; "
                                "generate it with `tune.py lint "
                                "--write-fingerprints`")]
    with open(path) as f:
        pinned = json.load(f)
    live = current_fingerprints()
    findings: List[Finding] = []
    for name, cur in live.items():
        pin = pinned.get(name)
        if pin is None:
            findings.append(Finding(
                rule=f"fingerprint.{name}", path=loc,
                message=f"contract {name!r} is not pinned; refresh the "
                        f"fixture with --write-fingerprints"))
            continue
        if cur["version"] != pin.get("version"):
            findings.append(Finding(
                rule=f"fingerprint.{name}", path=loc,
                message=f"{name}: version {cur['version']} != pinned "
                        f"{pin.get('version')} — the fixture is stale; "
                        f"refresh it with --write-fingerprints in the same "
                        f"change"))
        elif cur["hash"] != pin.get("hash"):
            findings.append(Finding(
                rule=f"fingerprint.{name}", path=loc,
                message=f"{name}: contract content changed but its version "
                        f"constant did not — bump the matching *_VERSION "
                        f"(artifacts recorded under version "
                        f"{cur['version']} would silently go stale), then "
                        f"refresh the fixture with --write-fingerprints"))
    for name in pinned:
        if name not in live:
            findings.append(Finding(
                rule=f"fingerprint.{name}", path=loc,
                message=f"fixture pins unknown contract {name!r}; refresh "
                        f"it with --write-fingerprints"))
    return findings
