"""Generic staged-execution driver: runs a StagePlan's launch list.

Generalizes the FFT four-step decomposition (paper §IV-C) into a driver
any prefix-family kernel can use, so large-N scan — and through the scan,
tridiag substitution sweeps, SSD phase-B and RG-LRU — also get the
m-kernel multi-pass path instead of only FFT:

  * ``four_step_fft``     — N = n1*n2 column/row decomposition, recursing
                            through the plan's children (m = 2 or 3);
  * ``multipass_scan_add`` / ``multipass_linrec`` — the three-launch
                            block-scan decomposition (chunk scan, carry
                            scan over chunk transfer operators, apply);
  * ``linrec_rows``       — the tuned linear-recurrence building block as
                            a library call for composite kernels (SSD
                            phase-B, tridiag LF sweeps), with the XLA
                            reference as fallback where the radix spaces
                            have no valid config (odd lengths).

Every pallas launch is announced to ``record_launch`` with the plan's
``Launch`` record; ``capture_launches`` lets the conformance tests assert
that what runs is exactly what the plan promised.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.space import Workload
from repro.kernels._compat import CompilerParams
from repro.kernels.blocks.plan import Launch, StagePlan

_TRACE = threading.local()


@contextlib.contextmanager
def capture_launches():
    """Collect every Launch executed in this thread under the context."""
    captured: List[Launch] = []
    prev = getattr(_TRACE, "sink", None)
    _TRACE.sink = captured
    try:
        yield captured
    finally:
        _TRACE.sink = prev


def record_launch(launch: Launch) -> None:
    sink = getattr(_TRACE, "sink", None)
    if sink is not None:
        sink.append(launch)


def launch(kernel_fn: Callable, record: Launch, *args, **kwargs):
    """Record ``record`` and invoke the (jitted) kernel wrapper."""
    record_launch(record)
    return kernel_fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Four-step FFT (plan-driven; moved here from kernels/fft/ops.py)
# ---------------------------------------------------------------------------

def _kernel_fft(x: jax.Array, plan: StagePlan, inverse: bool,
                interpret: bool) -> jax.Array:
    from repro.kernels.fft.kernel import fft_pallas
    re, im = jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)
    record_launch(plan.launches[0])
    yre, yim = fft_pallas(re, im, rows_per_program=plan.rows,
                          stages=plan.stages, inverse=inverse,
                          interpret=interpret)
    return (yre + 1j * yim).astype(jnp.complex64)


def dispatch_fft(x: jax.Array, plan: StagePlan, *, inverse: bool,
                 interpret: bool) -> jax.Array:
    """Run a (possibly multi-pass) FFT plan on complex (batch, n) rows."""
    if plan.kind == "fused":
        return _kernel_fft(x, plan, inverse, interpret)
    return four_step_fft(x, plan, inverse=inverse, interpret=interpret)


def four_step_fft(x: jax.Array, plan: StagePlan, *, inverse: bool,
                  interpret: bool) -> jax.Array:
    """Bailey four-step N = n1*n2: column FFTs, twiddle, row FFTs,
    transpose — the §IV-C m-kernel path, launch list == plan.launches."""
    col_plan, row_plan = plan.children
    batch, n = x.shape
    n1, n2 = row_plan.n, col_plan.n
    sign = 1.0 if inverse else -1.0
    v = x.reshape(batch, n2, n1)
    # kernel(s) 1: length-n2 FFTs down the columns (batch*n1 problems);
    # recurses when n2 itself exceeds the resident tile (m = 3, paper:
    # N >= 2^19 on the 48KB-tile device)
    vc = jnp.transpose(v, (0, 2, 1)).reshape(batch * n1, n2)
    vc = dispatch_fft(vc, col_plan, inverse=inverse, interpret=interpret)
    v = jnp.transpose(vc.reshape(batch, n1, n2), (0, 2, 1))
    # twiddle
    k2 = jnp.arange(n2).reshape(1, n2, 1)
    k1 = jnp.arange(n1).reshape(1, 1, n1)
    v = v * jnp.exp(sign * 2j * jnp.pi * (k1 * k2) / n).astype(jnp.complex64)
    # kernel 2: length-n1 FFTs along rows
    vr = dispatch_fft(v.reshape(batch * n2, n1), row_plan, inverse=inverse,
                      interpret=interpret)
    v = vr.reshape(batch, n2, n1)
    # transpose for self-sorting output
    return jnp.transpose(v, (0, 2, 1)).reshape(batch, n)


# ---------------------------------------------------------------------------
# Multi-pass block scan (three launches)
# ---------------------------------------------------------------------------

def _apply_add_kernel(y_ref, e_ref, o_ref):
    y = y_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    o_ref[...] = (y + e).astype(o_ref.dtype)


def _apply_linrec_kernel(h_ref, p_ref, e_ref, o_ref):
    h = h_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    o_ref[...] = (h + p * e).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def _apply_add(y, entry, *, rows: int, interpret: bool):
    batch, n = y.shape
    grid = (batch // rows,)
    return pl.pallas_call(
        _apply_add_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, n), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(y.shape, y.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(y, entry)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def _apply_linrec(h, prod, entry, *, rows: int, interpret: bool):
    batch, n = h.shape
    grid = (batch // rows,)
    row_spec = pl.BlockSpec((rows, n), lambda i: (i, 0))
    return pl.pallas_call(
        _apply_linrec_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct(h.shape, h.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(h, prod, entry)


def multipass_scan_add(x: jax.Array, plan: StagePlan, *, unroll: int = 1,
                       interpret: bool = False) -> jax.Array:
    """Prefix sum over (batch, n) as three kernels: per-chunk scans,
    exclusive scan over chunk sums, entry broadcast — HBM roundtrips
    between launches instead of a serialized carry chain."""
    from repro.kernels.scan.kernel import scan_add_pallas
    l1, l2, l3 = plan.launches
    batch, n = x.shape
    p, length = plan.seq_tiles, plan.tile_n
    # inter-launch carries round-trip through HBM; sub-f32 dtypes compute
    # the whole pipeline in f32 and quantize ONCE at the output, matching
    # the fused path's f32 VMEM carry scratch (bf16 chunk sums at
    # magnitude ~sqrt(n) would otherwise quantize every entry offset)
    xc = x.reshape(batch * p, length)
    if x.dtype != jnp.float32:
        xc = xc.astype(jnp.float32)
    record_launch(l1)
    y_local = scan_add_pallas(xc, rows_per_program=l1.block_shape[0],
                              tile_n=length, stages=l1.stages, unroll=unroll,
                              interpret=interpret)
    sums = y_local[:, -1].reshape(batch, p)
    record_launch(l2)
    # the carry scan's tile is the CHUNK COUNT p, not tile_n: the
    # workload-tuned unroll was fit to tile_n and can exceed p when the
    # plan was built with a small seq_limit — clamp to the l2 launch
    # record's own tile so the balanced-tree fold never outgrows it
    csums = scan_add_pallas(sums, rows_per_program=l2.block_shape[0],
                            tile_n=p, stages=l2.stages,
                            unroll=max(1, min(unroll, l2.block_shape[1])),
                            interpret=interpret)
    entry = jnp.pad(csums[:, :-1], ((0, 0), (1, 0))).reshape(batch * p, 1)
    record_launch(l3)
    y = _apply_add(y_local, entry, rows=l3.block_shape[0],
                   interpret=interpret)
    return y.reshape(batch, n).astype(x.dtype)


def multipass_linrec(a: jax.Array, b: jax.Array, plan: StagePlan, *,
                     gate: bool = False,
                     interpret: bool = False) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t as three kernels: per-chunk linrec (+ the
    chunk transfer operators), carry linrec over operators, apply.

    ``gate=True`` is the fused rglru chain: ``b`` carries the raw input u
    and the chunk kernel applies the RG-LRU gate in-tile (the carry and
    apply launches operate on transfer operators, untouched by the gate).
    """
    from repro.kernels.scan.kernel import (scan_linrec_pallas,
                                           scan_linrec_prod_pallas)
    l1, l2, l3 = plan.launches
    batch, n = a.shape
    p, length = plan.seq_tiles, plan.tile_n
    ac = a.reshape(batch * p, length)
    bc = b.reshape(batch * p, length)
    if a.dtype != jnp.float32:        # see multipass_scan_add: one-shot
        ac = ac.astype(jnp.float32)   # output quantization, f32 carries
        bc = bc.astype(jnp.float32)
    record_launch(l1)
    h_local, a_cum = scan_linrec_prod_pallas(
        ac, bc, rows_per_program=l1.block_shape[0], stages=l1.stages,
        gate=gate, interpret=interpret)
    # chunk transfer operator: state_out = A * state_in + B
    A = a_cum[:, -1].reshape(batch, p)
    B = h_local[:, -1].reshape(batch, p)
    record_launch(l2)
    exits = scan_linrec_pallas(A, B, rows_per_program=l2.block_shape[0],
                               tile_n=p, stages=l2.stages,
                               interpret=interpret)
    entry = jnp.pad(exits[:, :-1], ((0, 0), (1, 0))).reshape(batch * p, 1)
    record_launch(l3)
    h = _apply_linrec(h_local, a_cum, entry.astype(h_local.dtype),
                      rows=l3.block_shape[0], interpret=interpret)
    return h.reshape(batch, n).astype(a.dtype)


# ---------------------------------------------------------------------------
# Linear recurrence as a library building block
# ---------------------------------------------------------------------------

def _linrec_space_valid(n: int) -> bool:
    # the radix spaces have no valid config for odd lengths (pinned by
    # tests); composite kernels fall back to the XLA reference there
    return n >= 2 and n % 2 == 0


def linrec_rows(a: jax.Array, b: jax.Array, *, use_pallas: bool,
                interpret: bool, config: Optional[dict] = None) -> jax.Array:
    """Tuned linear recurrence over (rows, n) — the shared carry-chain
    block composite kernels (SSD phase-B, tridiag LF sweeps) call.

    Resolves the (op="scan", variant="linrec") workload through the
    session, builds its StagePlan, and dispatches fused or multi-pass
    exactly like the public ``linear_recurrence`` entry point.
    """
    from repro.kernels.scan.ref import scan_linrec_assoc_ref
    rows, n = a.shape
    if n <= 1:
        return b
    if not (use_pallas and _linrec_space_valid(n)):
        return scan_linrec_assoc_ref(a, b)
    from repro.kernels.scan.kernel import scan_linrec_pallas
    from repro.kernels.blocks.plan import plan_for
    from repro.tuning import default_session
    wl = Workload(op="scan", n=n, batch=rows, variant="linrec")
    cfg = default_session().resolve(wl, config=config)
    plan = plan_for(wl, cfg)
    if plan.kind == "multipass":
        return multipass_linrec(a, b, plan, interpret=interpret)
    return launch(scan_linrec_pallas, plan.launches[0], a, b,
                  rows_per_program=plan.rows, tile_n=plan.tile_n,
                  stages=plan.stages, interpret=interpret)
