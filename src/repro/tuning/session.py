"""TunerSession — the single config-resolution pipeline.

One object owns everything the paper's deployment story needs:

  * the persistent :class:`~repro.tuning.db.TuningDB` (offline winners),
  * the platform spec,
  * the resolution :class:`~repro.core.policy.Policy` (latency / energy /
    edp / memory_cap) — which metric axis resolve/tune optimize; winners
    are keyed per policy in the DB,
  * the search-strategy registry (bayesian / exhaustive / random /
    analytical — extensible via :func:`register_strategy`),
  * an in-memory LRU of fully resolved (normalized) configs, so the online
    hot path does not re-run the analytical model or re-fit dicts on every
    kernel call,
  * a memo of analytical suggestions per workload key (a DB miss consults
    the model once, not once per request).

Resolution order for ``resolve(wl)``:

  active ``overrides()``  >  explicit ``config=`` argument  >  LRU cache
  >  TuningDB entry  >  memoized analytical suggestion

(an explicit ``config`` replaces the DB/analytical base entirely; override
fragments then merge on top of whatever base was chosen) followed by the
op's registered normalizer, which fits the raw knobs to
the actual launch geometry. The process-wide default session is what the
kernel entry points and the legacy ``get_config`` shim use.
"""
from __future__ import annotations

import inspect
import threading
from collections import OrderedDict
from typing import Callable, Dict, Mapping, Optional, Tuple, Union

from repro.core.analytical import AnalyticalTuner
from repro.core.bayesian import BayesianTuner, TuneResult
from repro.core.exhaustive import ExhaustiveSearch, RandomSearch
from repro.core.objective import CachedObjective, CostModelObjective, Objective
from repro.core.policy import Policy, PolicyObjective, get_policy
from repro.core.space import Config, Workload, build_space
from repro.hw.profiles import HardwareProfile, active_profile, get_profile
from repro.tuning.db import TuningDB
from repro.tuning.overrides import active_overrides
from repro.tuning.registry import normalizer_for

# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------
# A strategy maps (space, objective, seed, max_evals, **sweep_kwargs) ->
# TuneResult. New search methods plug in via register_strategy without
# touching the session. Every strategy accepts (and may ignore) the sweep
# plumbing kwargs — journal_dir / prune / top_k — so the session can
# forward them uniformly.

Strategy = Callable[..., TuneResult]


def _bayesian(space, objective, *, seed: int = 0, max_evals: int = 64,
              **_sweep) -> TuneResult:
    return BayesianTuner(seed=seed, max_evals=max_evals).tune(space, objective)


def _exhaustive(space, objective, *, seed: int = 0, max_evals: int = 0,
                journal_dir=None, prune=None, top_k=None,
                policy=None) -> TuneResult:
    return ExhaustiveSearch(journal_dir=journal_dir, prune=prune,
                            top_k=top_k, policy=policy).tune(space, objective)


def _random(space, objective, *, seed: int = 0, max_evals: int = 64,
            **_sweep) -> TuneResult:
    return RandomSearch(max_evals=max_evals, seed=seed).tune(space, objective)


def _analytical(space, objective, *, seed: int = 0, max_evals: int = 0,
                **_sweep) -> TuneResult:
    cfg = AnalyticalTuner().suggest(space)
    m = objective(space, cfg)
    return TuneResult(cfg, m.time_s, 0, [(cfg, m.time_s)], "analytical")


def _online(space, objective, *, seed: int = 0, max_evals: int = 16,
            **_sweep) -> TuneResult:
    # lazy import (online pulls in the sweep journal stack). Simulates
    # in-traffic tuning against the objective: analytical prior, trial /
    # guard-band / rollback state machine, max_evals as the measurement
    # budget (see repro.tuning.online).
    from repro.tuning.online import online_search
    return online_search(space, objective, seed=seed, budget=max_evals)


def _ml(space, objective, *, seed: int = 0, max_evals: int = 0,
        **_sweep) -> TuneResult:
    # lazy import: the forest/feature stack only loads when strategy="ml" is
    # actually used. Resolution ladder: ml -> analytical -> default (see
    # repro.tuning.ml.strategy — the fallback is inside MLStrategy, so this
    # always returns a config even with no model artifact on disk).
    from repro.tuning.ml.strategy import default_strategy
    return default_strategy().tune(space, objective, seed=seed,
                                   max_evals=max_evals)


def _transfer(space, objective, *, seed: int = 0, max_evals: int = 64,
              journal_dir=None, **_sweep) -> TuneResult:
    # lazy import (the transfer stack pulls in the journal reader). Warm
    # start from OTHER devices' sweep journals in journal_dir, reweighted by
    # profile distance; falls back to cold Bayesian with no journals.
    from repro.core.transfer import transfer_strategy
    return transfer_strategy(space, objective, seed=seed,
                             max_evals=max_evals, journal_dir=journal_dir)


_STRATEGIES: Dict[str, Strategy] = {
    "bayesian": _bayesian,
    "exhaustive": _exhaustive,
    "random": _random,
    "analytical": _analytical,
    "ml": _ml,
    "online": _online,
    "transfer": _transfer,
}


def register_strategy(name: str, strategy: Strategy) -> None:
    _STRATEGIES[name] = strategy


def get_strategy(name: str) -> Strategy:
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise ValueError(f"unknown tuning method {name!r}; registered: "
                         f"{', '.join(strategies())}") from None


def strategies() -> Tuple[str, ...]:
    return tuple(sorted(_STRATEGIES))


# ---------------------------------------------------------------------------
# TunerSession
# ---------------------------------------------------------------------------

def _dims_token(dims: Optional[Mapping[str, int]]) -> Optional[Tuple]:
    return tuple(sorted(dims.items())) if dims else None


class TunerSession:
    """Owns the DB + caches; the one public way to resolve tuned configs."""

    def __init__(self, db: Optional[TuningDB] = None, *,
                 db_path: Optional[str] = None, platform: Optional[str] = None,
                 spec: Optional[HardwareProfile] = None,
                 cache_size: int = 2048, sweep_dir: Optional[str] = None,
                 policy: Union[str, Policy] = "latency"):
        # profile resolution: an explicit spec wins; else a platform naming a
        # registered profile; else the process-wide active profile. The DB
        # platform defaults to the profile name, so entries tuned for one
        # device are keyed apart from every other device's.
        if spec is None:
            try:
                spec = get_profile(platform) if platform is not None \
                    else active_profile()
            except ValueError:
                # a platform label that is not a registered profile (custom
                # DB namespaces) keys the DB but models as the active device
                spec = active_profile()
        self.spec = spec
        # the session's resolution policy: which axis of the metric vector
        # resolve()/tune() optimize by default (see repro.core.policy);
        # "latency" reproduces the scalar-era behavior exactly
        self.policy = get_policy(policy, spec)
        if platform is None:
            platform = spec.name
        self.db = db if db is not None else TuningDB(path=db_path,
                                                     platform=platform)
        self.platform = self.db.platform
        self.sweep_dir = sweep_dir   # journal directory for exhaustive sweeps
        self.cache_size = max(int(cache_size), 1)
        self._analytical = AnalyticalTuner()
        self._lock = threading.RLock()
        self._resolved: "OrderedDict[Tuple, Config]" = OrderedDict()
        self._suggested: Dict[str, Config] = {}
        self.hits = 0
        self.misses = 0

    # -- online path ---------------------------------------------------------

    def resolve(self, wl: Workload, *, config: Optional[Mapping[str, int]] = None,
                dims: Optional[Mapping[str, int]] = None) -> Config:
        """Launch-ready config for ``wl``: resolved, overridden, normalized."""
        wl = wl.canonical()
        ov = active_overrides(wl.op)
        cache_key = (wl.key, _dims_token(dims), self.policy.key)
        if config is None and ov is None:
            with self._lock:
                cached = self._resolved.get(cache_key)
                if cached is not None:
                    self._resolved.move_to_end(cache_key)
                    self.hits += 1
                    return dict(cached)
                self.misses += 1
        base = dict(config) if config is not None else self.resolve_raw(wl)
        if ov:
            base.update(ov)
        resolved = normalizer_for(wl.op)(base, wl, dims)
        if config is None and ov is None:
            with self._lock:
                self._resolved[cache_key] = dict(resolved)
                self._resolved.move_to_end(cache_key)
                while len(self._resolved) > self.cache_size:
                    self._resolved.popitem(last=False)
        return resolved

    def resolve_raw(self, wl: Workload) -> Config:
        """Pre-normalization config: DB hit (under the session policy),
        else memoized analytical."""
        wl = wl.canonical()
        cfg = self.db.lookup(wl, policy=self.policy.key)
        if cfg is not None:
            return cfg
        return dict(self.suggest(wl))

    def suggest(self, wl: Workload) -> Config:
        """Analytical (zero-evaluation) suggestion, memoized per workload."""
        wl = wl.canonical()
        with self._lock:
            cached = self._suggested.get(wl.key)
        if cached is not None:
            return dict(cached)
        cfg = self._analytical.suggest(build_space(wl, self.spec))
        with self._lock:
            self._suggested.setdefault(wl.key, dict(cfg))
        return cfg

    def lookup(self, wl: Workload,
               policy: Union[str, Policy, None] = None) -> Optional[Config]:
        pol = self.policy if policy is None else get_policy(policy, self.spec)
        return self.db.lookup(wl.canonical(), policy=pol.key)

    # -- offline path --------------------------------------------------------

    def tune(self, wl: Workload, method: str = "bayesian",
             objective: Optional[Objective] = None, *, seed: int = 0,
             max_evals: int = 64, store: bool = True,
             prune: Optional[str] = None, top_k: Optional[int] = None,
             policy: Union[str, Policy, None] = None) -> TuneResult:
        """Run an offline search; persist the winner; invalidate the caches.

        Exhaustive searches journal to ``self.sweep_dir`` (when set), so
        interrupted sweeps resume, and honour ``prune``/``top_k``
        (analytical-dominance pruning); other strategies ignore both.

        ``policy`` (default: the session's) decides what the search
        minimizes.  Exhaustive sweeps stay keyed by the raw objective and
        pick the winner from the Pareto front — one journal serves every
        policy; every other strategy searches through a
        :class:`~repro.core.policy.PolicyObjective` wrapper.  Winners are
        stored under policy-namespaced DB keys (latency keys unchanged).
        """
        wl = wl.canonical()
        pol = self.policy if policy is None else get_policy(policy, self.spec)
        strategy = get_strategy(method)
        space = build_space(wl, self.spec)
        cached = CachedObjective(objective or CostModelObjective(self.spec))
        search_obj: Objective = cached
        if pol.name != "latency" and method != "exhaustive":
            search_obj = PolicyObjective(cached, pol)
        extra = {"journal_dir": self.sweep_dir, "prune": prune,
                 "top_k": top_k,
                 "policy": pol if pol.name != "latency" else None}
        try:     # strategies registered before the sweep kwargs existed
            params = inspect.signature(strategy).parameters
            if not any(p.kind is p.VAR_KEYWORD for p in params.values()):
                extra = {k: v for k, v in extra.items() if k in params}
        except (TypeError, ValueError):
            pass
        result = strategy(space, search_obj, seed=seed, max_evals=max_evals,
                          **extra)
        if store:
            # a pruned sweep's winner is NOT a guaranteed optimum; don't
            # store it under the method name dataset_from_db trusts for
            # label-0.0 ("this is the group best") training rows
            stored_method = f"{method}-pruned" \
                if result.stopped_by == "pruned" else method
            # the winner's metric vector (a cache hit for any measured
            # winner). Under a non-latency policy result.best_time is the
            # policy scalar — the DB's time_s must stay real seconds.
            m = cached(space, result.best_config)
            time_s = result.best_time if pol.name == "latency" \
                else (m.time_s if m.valid else result.best_time)
            self.db.store(wl, result.best_config, time_s,
                          stored_method, result.evaluations,
                          metrics=dict(m.metrics) if m.valid else None,
                          policy=pol.key)
            self.invalidate(wl)
        return result

    # -- cache management ----------------------------------------------------

    def invalidate(self, wl: Workload) -> None:
        wl = wl.canonical()
        with self._lock:
            for key in [k for k in self._resolved if k[0] == wl.key]:
                del self._resolved[key]

    def clear_cache(self) -> None:
        with self._lock:
            self._resolved.clear()
            self._suggested.clear()
            self.hits = self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "resolved": len(self._resolved),
                    "suggested": len(self._suggested),
                    "db_entries": len(self.db)}


# ---------------------------------------------------------------------------
# Default (process-wide) session
# ---------------------------------------------------------------------------

_DEFAULT: Optional[TunerSession] = None
_DEFAULT_LOCK = threading.Lock()


def default_session() -> TunerSession:
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = TunerSession()
    return _DEFAULT


def set_default_session(session: Optional[TunerSession]) -> Optional[TunerSession]:
    """Swap the process-wide session; returns the previous one."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        previous, _DEFAULT = _DEFAULT, session
    return previous
