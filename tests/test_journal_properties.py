"""Property tests: the sweep journal never loses a committed entry.

Hypothesis drives arbitrary interleavings of the failure modes a
long-running sweep actually sees — chunk appends, kill -9 mid-append
(a torn, newline-less tail), process restarts (fresh SweepJournal
instances against the same file) — and asserts, after every sequence:

  * every committed (fully appended) entry is still loaded, with the
    last-written time winning;
  * ``entries()`` never double-counts a config, no matter how many
    concurrent-writer-style duplicate appends happened;
  * foreign headers are never silently resumed: a workload/objective
    mismatch raises, a headerless/torn-header journal is quarantined.

Run with ``HYPOTHESIS_PROFILE=ci`` (registered in tests/conftest.py) for
a fixed derandomized seed and no deadline — deterministic in CI.
"""
import os
import tempfile

import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import TPUCostModelObjective, Workload, build_space
from repro.tuning.sweep import SweepJournal, config_key

WL = Workload(op="fft", n=256, batch=2**14, variant="stockham")
OTHER_WL = Workload(op="fft", n=512, batch=2**14, variant="stockham")
OBJ = TPUCostModelObjective()
SPACE = build_space(WL)
CONFIGS = SPACE.enumerate_valid()[:16]
SPACE_SIZE = len(SPACE.enumerate_valid())

# an op is one of:
#   ("append", [(config_index, time), ...])  — a committed chunk append
#   ("tear",)                                — kill -9 mid-write: torn tail
#   ("reopen",)                              — process restart: new instance
_entry = st.tuples(st.integers(0, len(CONFIGS) - 1),
                   st.floats(1e-6, 1e-2, allow_nan=False))
_op = st.one_of(
    st.tuples(st.just("append"), st.lists(_entry, min_size=1, max_size=5)),
    st.tuples(st.just("tear")),
    st.tuples(st.just("reopen")),
)


def _apply(journal, path, committed, op):
    kind = op[0]
    if kind == "append":
        entries = [(CONFIGS[i], t) for i, t in op[1]]
        journal.append(WL, OBJ, SPACE_SIZE, entries)
        for cfg, t in entries:
            committed[config_key(cfg)] = float(t)
        return journal
    if kind == "tear":
        with open(path, "a") as f:
            f.write('{"k": "torn-mid-wri')       # no newline: a torn tail
        return journal
    return SweepJournal(path)                    # reopen


@settings(max_examples=40, deadline=None)
@given(st.lists(_op, min_size=1, max_size=12))
def test_committed_entries_survive_any_interleaving(ops):
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.jsonl")
        journal = SweepJournal(path)
        committed = {}
        for op in ops:
            journal = _apply(journal, path, committed, op)
        loaded = journal.load(WL, OBJ)
        assert loaded == committed, \
            "a committed entry was lost or corrupted by the interleaving"
        # a restart sees the same state
        assert SweepJournal(path).load(WL, OBJ) == committed


@settings(max_examples=40, deadline=None)
@given(st.lists(_op, min_size=1, max_size=12))
def test_entries_never_double_count_after_dedup(ops):
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.jsonl")
        journal = SweepJournal(path)
        committed = {}
        for op in ops:
            journal = _apply(journal, path, committed, op)
        pairs = SweepJournal(path).entries()
        keys = [config_key(cfg) for cfg, _ in pairs]
        assert len(keys) == len(set(keys)), "entries() double-counted"
        assert {k: t for k, t in
                zip(keys, (t for _, t in pairs))} == committed


@settings(max_examples=20, deadline=None)
@given(st.lists(_entry, min_size=1, max_size=6),
       st.booleans())
def test_foreign_headers_always_rejected(entries, wrong_objective):
    """A journal written under a different workload or objective must
    raise on load — silently resuming foreign numbers corrupts optima."""
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.jsonl")
        journal = SweepJournal(path)
        journal.append(WL, OBJ, SPACE_SIZE,
                       [(CONFIGS[i], t) for i, t in entries])
        if wrong_objective:
            with pytest.raises(ValueError, match="objective"):
                journal.load(WL, TPUCostModelObjective(noise=0.5))
        else:
            with pytest.raises(ValueError, match="workload"):
                journal.load(OTHER_WL, OBJ)


@settings(max_examples=20, deadline=None)
@given(st.text(alphabet="abc{}\": ,0123456789", min_size=0, max_size=40))
def test_headerless_garbage_quarantined_not_resumed(garbage):
    """Whatever bytes land in a journal without a parseable header, a
    validated load must quarantine the file and return nothing."""
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.jsonl")
        with open(path, "w") as f:
            f.write(garbage)
        journal = SweepJournal(path)
        header = journal.read_header()
        if header is not None:
            return                      # the garbage parsed as a header
        loaded = journal.load(WL, OBJ)
        assert loaded == {}
        if garbage.strip():
            assert os.path.exists(path + ".corrupt"), \
                "unvalidatable bytes must be quarantined, not left live"
            assert not os.path.exists(path)

# metric-vector entries (journal v3): an append row is either a legacy
# (config, time) pair or a (config, time, vector) triple with an energy
# axis — both shapes interleave freely in one journal
_mentry = st.tuples(st.integers(0, len(CONFIGS) - 1),
                    st.floats(1e-6, 1e-2, allow_nan=False),
                    st.one_of(st.none(),
                              st.floats(1e-9, 1e3, allow_nan=False)))
_mop = st.one_of(
    st.tuples(st.just("append"), st.lists(_mentry, min_size=1, max_size=5)),
    st.tuples(st.just("tear")),
    st.tuples(st.just("reopen")),
)


def _apply_metrics(journal, path, committed, op):
    kind = op[0]
    if kind == "append":
        rows = []
        for i, t, e in op[1]:
            cfg = CONFIGS[i]
            if e is None:                    # pre-vector writer: bare pair
                rows.append((cfg, t))
                committed[config_key(cfg)] = {"time_s": float(t)}
            else:
                vec = {"time_s": float(t), "energy_j": float(e)}
                rows.append((cfg, t, vec))
                committed[config_key(cfg)] = vec
        journal.append(WL, OBJ, SPACE_SIZE, rows)
        return journal
    if kind == "tear":
        with open(path, "a") as f:
            f.write('{"k": "torn-mid-wri')
        return journal
    return SweepJournal(path)


@settings(max_examples=40, deadline=None)
@given(st.lists(_mop, min_size=1, max_size=12))
def test_metric_vectors_survive_any_interleaving(ops):
    """Committed metric vectors round-trip through any interleaving of
    appends, torn tails, and restarts; pair-shaped (pre-vector) entries
    load as time_s-only vectors; the scalar ``load``/``entries`` views
    stay the exact time_s projection of the vector views."""
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "journal.jsonl")
        journal = SweepJournal(path)
        committed = {}
        for op in ops:
            journal = _apply_metrics(journal, path, committed, op)
        assert journal.load_metrics(WL, OBJ) == committed
        assert journal.load(WL, OBJ) == \
            {k: v["time_s"] for k, v in committed.items()}
        # fresh instance: vector and scalar entry views are positionally
        # parallel and agree with the committed state
        pairs = SweepJournal(path).metric_entries()
        assert {config_key(c): v for c, v in pairs} == committed
        assert [(c, v["time_s"]) for c, v in pairs] \
            == SweepJournal(path).entries()
