"""qwen2-moe-a2.7b: 24L d_model=2048 16H (kv=16) d_ff=1408(expert)
vocab=151936, 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, d_ff_expert=1408, vocab=151936, activation="swiglu",
    n_experts=60, n_shared_experts=4, moe_top_k=4, qkv_bias=True,
))
