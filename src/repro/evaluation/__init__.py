"""repro.evaluation — cross-methodology evaluation harnesses.

``repro.evaluation.compare`` reproduces the paper's Table-II-style
comparison: every tuning methodology scored against the exhaustive
optimum (Phi, mean slowdown, evaluation counts), plus the per-(device,
method) matrix over hardware profiles (the portability story).
"""
from repro.evaluation.compare import (check_matrix, check_report,
                                      compare_methods,
                                      compare_methods_matrix,
                                      evals_to_optimum, format_matrix,
                                      format_report)

__all__ = ["check_report", "compare_methods", "format_report",
           "compare_methods_matrix", "check_matrix", "format_matrix",
           "evals_to_optimum"]
