"""Transfer tuning: multi-task warm-starting of the BO search (paper §IV-B).

The paper uses GPTune, whose Linear Coregionalization Model shares a
surrogate ACROSS tasks (problem sizes), so tuning size N starts from what
sizes N/2 and 2N already taught it. We reproduce the effect with a
transfer-GP: prior observations from neighbouring workloads enter the
training set with a task-distance kernel weight, and the acquisition is
optimized as usual. The practical win mirrors the paper's online story —
amortizing evaluations across repeated invocations of a routine family.

Task encoding: log2(N) normalized over the family's size range; the task
kernel is RBF over that coordinate, so closer sizes transfer more.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bayesian import GP, TuneResult, expected_improvement
from repro.core.objective import Objective, PENALTY_TIME
from repro.core.space import Config, SearchSpace, Workload, build_space


@dataclasses.dataclass
class TaskHistory:
    workload: Workload
    configs: List[Config]
    times: List[float]


class TransferBayesianTuner:
    """BO with cross-size transfer. `histories` hold (workload, config,
    time) observations from already-tuned sizes of the same op family."""

    name = "transfer"

    def __init__(self, n_init: int = 2, patience: int = 5, max_evals: int = 64,
                 seed: int = 0, task_lengthscale: float = 0.75):
        self.n_init = n_init
        self.patience = patience
        self.max_evals = max_evals
        self.seed = seed
        self.task_ls = task_lengthscale

    def _task_coord(self, wl: Workload) -> float:
        return math.log2(max(wl.n, 1)) / 24.0

    def tune(self, space: SearchSpace, objective: Objective,
             histories: Sequence[TaskHistory] = ()) -> TuneResult:
        rng = np.random.default_rng(self.seed)
        candidates = space.enumerate_valid()
        if not candidates:
            raise ValueError("empty space")
        enc = np.array([space.encode(c) for c in candidates])
        t_here = self._task_coord(space.workload)
        enc_aug = np.concatenate(
            [enc, np.full((len(enc), 1), 0.0)], axis=1)  # task delta 0

        # transfer set: neighbour observations, with their encoded config in
        # THIS space's coordinates when compatible, plus task-delta feature
        xs_prior: List[np.ndarray] = []
        ys_prior: List[float] = []
        for hist in histories:
            dt = (self._task_coord(hist.workload) - t_here) / self.task_ls
            for cfg, t in zip(hist.configs, hist.times):
                try:
                    x = space.encode({k: cfg.get(k, 0) for k in
                                      [p.name for p in space.params]})
                except Exception:
                    continue
                xs_prior.append(np.array(x + [dt]))
                ys_prior.append(t)

        history: List[Tuple[Config, float]] = []
        evaluated: Dict[int, float] = {}

        def measure(idx: int) -> float:
            m = objective(space, candidates[idx])
            t = m.time_s if m.valid else PENALTY_TIME
            evaluated[idx] = t
            history.append((candidates[idx], t))
            return t

        # warm bootstrap: rank candidates by the transfer-GP posterior mean
        # (zero fresh evaluations spent on ranking)
        order = rng.permutation(len(candidates))
        if xs_prior:
            gp0 = GP(lengthscale=0.5).fit(np.array(xs_prior),
                                          np.log(np.array(ys_prior)))
            mu0, _ = gp0.predict(enc_aug)
            order = np.argsort(mu0)      # most promising first
        for idx in order[: min(self.n_init, len(candidates))]:
            measure(int(idx))

        best_idx = min(evaluated, key=evaluated.get)
        best_t = evaluated[best_idx]
        since = 0
        stopped = "exhausted"
        while len(evaluated) < min(self.max_evals, len(candidates)):
            if since >= self.patience:
                stopped = "sliding_window"
                break
            xs = [list(enc[i]) + [0.0] for i in evaluated]
            ys = list(np.log(np.array(list(evaluated.values()))))
            xs_all = np.array(xs_prior + [np.array(x) for x in xs]) \
                if xs_prior else np.array(xs)
            ys_log_prior = [float(v) for v in np.log(np.asarray(ys_prior))] \
                if ys_prior else []
            ys_all = ys_log_prior + ys
            gp = GP(lengthscale=0.5).fit(np.asarray(xs_all, float),
                                         np.asarray(ys_all, float))
            remaining = [i for i in range(len(candidates))
                         if i not in evaluated]
            mu, sigma = gp.predict(enc_aug[remaining])
            ei = expected_improvement(mu, sigma, math.log(best_t))
            pick = remaining[int(np.argmax(ei))]
            t = measure(pick)
            if t < best_t * (1 - 1e-9):
                best_t, best_idx = t, pick
                since = 0
            else:
                since += 1
        else:
            # same semantics as BayesianTuner: "max_evals" when the budget
            # bound, "exhausted" only when the space truly ran out
            stopped = "max_evals" if len(evaluated) >= self.max_evals \
                else "exhausted"
        return TuneResult(candidates[best_idx], best_t, len(evaluated),
                          history, stopped)


def tune_family(op: str, variant: str, sizes: Sequence[int],
                batch_of, objective_factory, seed: int = 0
                ) -> Dict[int, TuneResult]:
    """Tune a family of sizes in order, transferring histories forward —
    the amortized online flow the paper describes for iterative callers."""
    histories: List[TaskHistory] = []
    out: Dict[int, TuneResult] = {}
    for n in sizes:
        wl = Workload(op=op, n=n, batch=batch_of(n), variant=variant)
        space = build_space(wl)
        tuner = TransferBayesianTuner(seed=seed)
        res = tuner.tune(space, objective_factory(), histories)
        out[n] = res
        histories.append(TaskHistory(
            wl, [c for c, _ in res.history], [t for _, t in res.history]))
    return out
