"""repro.tuning.ml: features, forest, dataset, strategy, evaluation."""
import numpy as np
import pytest

from repro.core import TPUCostModelObjective, Workload, build_space
from repro.core.objective import Objective
from repro.tuning.ml import (FEATURE_NAMES, MLStrategy, ModelArtifactError,
                             ModelBundle, N_FEATURES, build_dataset,
                             check_floors, dataset_from_db, evaluate_model,
                             featurize, featurize_batch, merge, parse_db_key,
                             split_by_size, suite_workloads, sweep_workload,
                             train_bundle)
from repro.tuning.ml.dataset import POOLED_OPS, SUITE
from repro.tuning.ml.forest import Forest


class CountingObjective(Objective):
    """Fails the test if the 'zero online evaluations' contract is broken."""

    def __init__(self):
        self.calls = 0
        self.inner = TPUCostModelObjective()

    def __call__(self, space, cfg):
        self.calls += 1
        return self.inner(space, cfg)


def _wl(op="scan", n=256, batch=4096, variant="ks"):
    return Workload(op=op, n=n, batch=batch, variant=variant)


# ---------------------------------------------------------------------------
# fixtures: one tiny bundle shared by the strategy/eval tests
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_bundle():
    """Small-but-complete bundle: every op, reduced sizes and trees."""
    workloads = []
    for op, spec in SUITE.items():
        for variant in spec["variants"][:1]:
            for n in spec["train"][:2]:
                batch = spec.get("batch") or max(2 ** 26 // n, 1)
                workloads.append(Workload(op=op, n=n, batch=batch,
                                          variant=variant))
    ds = build_dataset(workloads)
    return train_bundle(ds.by_op(), n_trees=8, max_depth=10, seed=0,
                        meta={"aliases": POOLED_OPS})


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------

def test_featurize_deterministic_fixed_length():
    wl = _wl().canonical()
    space = build_space(wl)
    cfgs = space.enumerate_valid()
    X1 = featurize_batch(space, cfgs)
    X2 = featurize_batch(space, cfgs)
    assert X1.shape == (len(cfgs), N_FEATURES)
    assert len(FEATURE_NAMES) == N_FEATURES
    np.testing.assert_array_equal(X1, X2)
    assert np.isfinite(X1).all()


def test_featurize_batch_context_columns():
    wl = _wl().canonical()
    space = build_space(wl)
    cfgs = space.enumerate_valid()
    X = featurize_batch(space, cfgs)
    pct = X[:, FEATURE_NAMES.index("ana_rank_pct")]
    # a full percentile sweep: best candidate 1.0, worst 0.0
    assert pct.max() == pytest.approx(1.0) and pct.min() == pytest.approx(0.0)
    for col in ("tier_rel", "radix_rank_rel", "block_rank_rel",
                "dma_eff_rel"):
        rel = X[:, FEATURE_NAMES.index(col)]
        assert rel.max() == pytest.approx(0.0)  # relative to the best present
        assert (rel <= 0).all()
    # single-row featurize keeps neutral context defaults
    row = featurize(space, cfgs[0])
    assert row[FEATURE_NAMES.index("ana_rank_pct")] == 1.0


# ---------------------------------------------------------------------------
# forest
# ---------------------------------------------------------------------------

def test_forest_fits_simple_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(-1, 1, size=(600, 5))
    y = 2.0 * X[:, 0] + (X[:, 1] > 0).astype(float)
    forest = Forest.fit(X, y, n_trees=12, max_depth=8, seed=0)
    mean, std = forest.predict(X)
    assert float(np.mean((mean - y) ** 2)) < 0.05
    assert std.shape == mean.shape == (len(X),)


def test_bundle_save_load_roundtrip(tmp_path, tiny_bundle):
    path = str(tmp_path / "model.npz")
    tiny_bundle.save(path)
    loaded = ModelBundle.load(path)
    assert set(loaded.ops()) == set(tiny_bundle.ops())
    wl = _wl().canonical()
    space = build_space(wl)
    cfgs = space.enumerate_valid()
    X = featurize_batch(space, cfgs)
    m1, s1 = tiny_bundle.forest_for("scan").predict(X)
    m2, s2 = loaded.forest_for("scan").predict(X)
    np.testing.assert_allclose(m1, m2)
    np.testing.assert_allclose(s1, s2)


def test_bundle_load_rejects_missing_and_stale(tmp_path, tiny_bundle):
    with pytest.raises(ModelArtifactError):
        ModelBundle.load(str(tmp_path / "nope.npz"))
    path = str(tmp_path / "stale.npz")
    tiny_bundle.meta["feature_version"] = -1
    try:
        tiny_bundle.save(path)
        with pytest.raises(ModelArtifactError):
            ModelBundle.load(path)
    finally:
        from repro.tuning.ml.features import FEATURE_VERSION
        tiny_bundle.meta["feature_version"] = FEATURE_VERSION


def test_bundle_aliases_route_pooled_ops(tiny_bundle):
    assert tiny_bundle.forest_for("ssd") is tiny_bundle.forest_for("scan")
    assert tiny_bundle.forest_for("rglru") is tiny_bundle.forest_for("scan")
    assert tiny_bundle.forest_for("unknown-op") is None


# ---------------------------------------------------------------------------
# dataset
# ---------------------------------------------------------------------------

def test_dataset_labels_are_log_slowdown_per_group():
    ds = build_dataset([_wl(n=128, batch=1024), _wl(n=256, batch=2048)])
    assert len(ds.keys) == 2
    for gid in range(len(ds.keys)):
        labels = ds.y[ds.group == gid]
        assert labels.min() == pytest.approx(0.0)   # winner pinned at 0
        assert (labels >= 0).all()


def test_dataset_merge_and_split_by_size():
    a = build_dataset([_wl(n=128, batch=1024)])
    b = build_dataset([_wl(n=256, batch=2048)])
    m = merge(a, b)
    assert len(m) == len(a) + len(b) and len(m.keys) == 2
    wls = [_wl(n=n, batch=1024) for n in (128, 256, 512)]
    train, hold = split_by_size(wls, {"scan": [256]})
    assert [w.n for w in hold] == [256]
    assert sorted(w.n for w in train) == [128, 512]


def test_suite_holdout_sizes_disjoint_from_train():
    for op, spec in SUITE.items():
        assert not set(spec["train"]) & set(spec["holdout"]), op


def test_suite_covers_every_registered_op():
    """Registering a new @tuned_kernel op without declaring train/holdout
    sizes in SUITE must fail here, not silently skip training for it."""
    from repro.tuning.registry import known_ops
    assert set(SUITE) == set(known_ops())


def test_suite_workloads_rejects_unknown_op():
    with pytest.raises(ValueError, match="atention"):
        suite_workloads("train", ops=["scan", "atention"])


def test_parse_db_key_roundtrip():
    wl = _wl(op="fft", n=1024, batch=65536, variant="stockham").canonical()
    parsed = parse_db_key(f"tpu_v5e|{wl.key}")
    assert parsed == wl
    assert parse_db_key("garbage") is None
    assert parse_db_key("tpu_v5e|scan:default:nX:b1:float32") is None


def test_dataset_from_db(tmp_path):
    from repro.tuning.db import TuningDB
    db = TuningDB(path=str(tmp_path / "db.json"))
    wl = _wl().canonical()
    cfgs, _, times = sweep_workload(wl)
    i = int(np.argmin(times))
    db.store(wl, cfgs[i], float(times[i]), "exhaustive", len(cfgs))
    db.store(_wl(op="nope", n=64, batch=1), {"tile_n": 64}, 1e-4, "x", 1)
    # a bayesian winner is NOT the proven group optimum: labeling it 0.0
    # would teach the forest a mediocre pattern is optimal, so it's skipped
    db.store(_wl(n=512, batch=2048).canonical(), cfgs[i], 2e-4, "bayesian", 8)
    ds = dataset_from_db(db)
    assert len(ds) == 1                      # unknown op + non-exhaustive skipped
    assert ds.ops == ["scan"]
    assert ds.X.shape == (1, N_FEATURES)
    assert len(dataset_from_db(db, methods=("exhaustive", "bayesian"))) == 2


# ---------------------------------------------------------------------------
# strategy: zero evaluations, fallback ladder
# ---------------------------------------------------------------------------

ALL_OPS = ("scan", "tridiag", "fft", "large_fft", "ssd", "rglru",
           "attention", "matmul")


def test_ml_strategy_zero_evaluations_all_ops(tiny_bundle):
    """Acceptance: strategy='ml' resolves every registered op with zero
    online kernel evaluations — ``choose`` never touches an objective at
    all, and ``tune`` spends exactly one measurement on the winner so the
    persisted time_s is real seconds (search evaluations stay 0)."""
    strategy = MLStrategy(model=tiny_bundle)
    for op in ALL_OPS:
        spec = SUITE[op]
        n = spec["holdout"][0]
        batch = spec.get("batch") or max(2 ** 26 // n, 1)
        wl = Workload(op=op, n=n, batch=batch,
                      variant=spec["variants"][0]).canonical()
        space = build_space(wl)
        cfgs = space.enumerate_valid()
        pick, rung = strategy.choose(space, cfgs)     # no objective exists
        assert rung in ("ml", "ml-defer-analytical"), op
        assert space.is_valid(cfgs[pick]), op

        counting = CountingObjective()
        res = strategy.tune(space, counting)
        assert counting.calls == 1, op                # winner measured once
        assert res.evaluations == 0, op               # zero search evals
        assert res.stopped_by == rung, op
        assert res.best_config == dict(cfgs[pick]), op
        # best_time is that single real measurement, not a relative score
        assert res.best_time == counting.inner(space, res.best_config).time_s


def test_ml_strategy_fallback_no_model(tmp_path):
    strategy = MLStrategy(model_path=str(tmp_path / "missing.npz"))
    wl = _wl().canonical()
    space = build_space(wl)
    counting = CountingObjective()
    res = strategy.tune(space, counting)
    assert res.stopped_by == "ml-fallback:no-model"
    assert counting.calls == 1                 # analytical fallback measures
    assert space.is_valid(res.best_config)


def test_ml_strategy_fallback_no_forest(tiny_bundle):
    bundle = ModelBundle({"scan": tiny_bundle.forests["scan"]}, {})
    strategy = MLStrategy(model=bundle)
    wl = _wl(op="matmul", n=512, batch=512, variant="").canonical()
    res = strategy.tune(build_space(wl), CountingObjective())
    assert res.stopped_by == "ml-fallback:no-forest:matmul"


def test_ml_strategy_fallback_low_confidence(tiny_bundle):
    strategy = MLStrategy(model=tiny_bundle, max_std=-1.0)
    wl = _wl().canonical()
    res = strategy.tune(build_space(wl), CountingObjective())
    assert res.stopped_by == "ml-fallback:low-confidence"


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def test_evaluate_model_report_shape_and_floors(tiny_bundle):
    wls = [w for w in suite_workloads("holdout", ops=["scan", "fft"])]
    report = evaluate_model(tiny_bundle, wls)
    assert report["n_scored"] == len(wls)
    assert 0.0 <= report["top1_rate"] <= 1.0
    assert report["mean_slowdown"] >= 1.0
    assert sum(report["rungs"].values()) == len(wls)
    assert report["ml_rate"] == 1.0            # trained ops: no fallbacks
    assert -1.0 <= report["mean_rank_corr"] <= 1.0
    # quality guard on the tiny model; CI pins the real floors (0.70/1.15)
    # on the fully-trained artifact
    assert report["mean_slowdown"] <= 1.10
    assert not check_floors(report, max_mean_slowdown=1.10, min_ml_rate=0.9)
    failures = check_floors(report, min_top1=1.01)
    assert failures and "top-1" in failures[0]


def test_evaluate_model_counts_fallbacks_against_ml_rate(tiny_bundle):
    """A model whose predictions are all low-confidence still gets scored
    (the analytical fallback is what ships) but cannot pass an ml_rate
    floor — the gate the CI job pins."""
    bundle = ModelBundle(tiny_bundle.forests,
                         dict(tiny_bundle.meta, aliases=POOLED_OPS))
    wls = suite_workloads("holdout", ops=["scan"])
    strategy_report = evaluate_model(bundle, wls)
    assert strategy_report["ml_rate"] == 1.0
    # drop the scan forest: every scan workload must fall back, be scored,
    # and drag ml_rate to 0
    no_scan = ModelBundle({op: f for op, f in tiny_bundle.forests.items()
                           if op != "scan"}, {})
    report = evaluate_model(no_scan, wls)
    assert report["n_scored"] == len(wls)      # fallbacks are not dropped
    assert report["ml_rate"] == 0.0
    assert all(r["rung"].startswith("ml-fallback:no-forest")
               for r in report["workloads"])
    failures = check_floors(report, min_ml_rate=0.9)
    assert failures and "learned-rung rate" in failures[0]
    # with no forest there is no learned ranking to correlate either
    assert report["mean_rank_corr"] == 0.0
    assert check_floors(report, min_rank_corr=0.8)


def test_check_floors_empty_report():
    assert check_floors({"n_scored": 0}, min_top1=0.5)


# ---------------------------------------------------------------------------
# Sweep-journal consumption (the dataset pipeline rides the sweep engine)
# ---------------------------------------------------------------------------

def test_sweep_workload_journals_and_resumes(tmp_path):
    from repro.tuning.ml.dataset import sweep_workload

    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    cfgs, X, times = sweep_workload(wl, TPUCostModelObjective(),
                                    journal_dir=str(tmp_path))
    assert len(cfgs) == len(times) == len(X)

    class Boom(TPUCostModelObjective):
        def batch_eval_metrics(self, *a, **kw):
            raise AssertionError("journal was ignored: re-evaluated")

        def signature(self):
            return TPUCostModelObjective().signature()

    cfgs2, X2, times2 = sweep_workload(wl, Boom(),
                                       journal_dir=str(tmp_path))
    assert cfgs2 == cfgs
    assert np.array_equal(times, times2)
    assert np.array_equal(X, X2)


def test_dataset_from_journal_dir_matches_direct_build(tmp_path):
    from repro.tuning.ml import build_dataset
    from repro.tuning.ml.dataset import dataset_from_journal_dir

    wls = [Workload(op="fft", n=256, batch=2**14, variant="stockham"),
           Workload(op="tridiag", n=128, batch=2**13, variant="wm")]
    direct = build_dataset(wls, TPUCostModelObjective(),
                           journal_dir=str(tmp_path))
    replayed = dataset_from_journal_dir(str(tmp_path))
    assert len(replayed) == len(direct) > 0
    assert sorted(replayed.keys) == sorted(direct.keys)
    # group-centered labels: every journal group pins its winner at 0.0
    for gid in range(len(replayed.keys)):
        assert replayed.y[replayed.group == gid].min() == 0.0
    # same rows, independent of file ordering
    assert np.isclose(np.sort(replayed.y), np.sort(direct.y)).all()


def test_dataset_from_journal_dir_filters_by_objective(tmp_path):
    """Sweeps of one workload under different objectives must not merge
    into duplicate groups with conflicting labels."""
    from repro.tuning.ml.dataset import (dataset_from_journal_dir,
                                         sweep_workload)

    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    clean = TPUCostModelObjective()
    noisy = TPUCostModelObjective(noise=0.1)
    sweep_workload(wl, clean, journal_dir=str(tmp_path))
    sweep_workload(wl, noisy, journal_dir=str(tmp_path))
    assert len(list(tmp_path.glob("*.jsonl"))) == 2

    only_clean = dataset_from_journal_dir(str(tmp_path), objective=clean)
    assert len(only_clean.keys) == 1            # one group, one objective
    unfiltered = dataset_from_journal_dir(str(tmp_path))
    assert len(unfiltered.keys) == 2            # caller opted into both


def test_partial_journal_features_match_full_space_context(tmp_path):
    """Space-context rank features must be computed against the FULL valid
    set even when the journal only holds part of a sweep — the same config
    must featurize identically in training and at predict time."""
    from repro.core.space import build_space
    from repro.tuning.ml import featurize_batch
    from repro.tuning.ml.dataset import dataset_from_journal
    from repro.tuning.sweep import SweepJournal, config_key

    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    space = build_space(wl)
    obj = TPUCostModelObjective()
    all_cfgs = space.enumerate_valid()
    journal = SweepJournal.for_workload(str(tmp_path), wl, obj)
    partial = all_cfgs[: len(all_cfgs) // 3]
    journal.append(wl, obj, len(all_cfgs),
                   [(c, obj(space, c).time_s) for c in partial])
    # duplicate appends (two concurrent writers): must not double rows
    journal.append(wl, obj, len(all_cfgs),
                   [(partial[0], obj(space, partial[0]).time_s)])

    ds = dataset_from_journal(journal.path)
    assert len(ds) == len(partial)                 # deduped
    X_full = featurize_batch(space, all_cfgs)
    index = {config_key(c): i for i, c in enumerate(all_cfgs)}
    expect = X_full[[index[config_key(c)] for c in partial]]
    assert np.array_equal(ds.X, expect)


def test_dataset_from_journal_skips_garbage(tmp_path):
    from repro.tuning.ml.dataset import dataset_from_journal

    bad = tmp_path / "corrupt.jsonl"
    bad.write_text("not json at all\n")
    assert len(dataset_from_journal(str(bad))) == 0
    missing = tmp_path / "nope.jsonl"
    assert len(dataset_from_journal(str(missing))) == 0
