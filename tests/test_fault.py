"""Fault-tolerance logic with simulated clocks/failures."""
import pytest

from repro.train.fault import (FaultInjector, HeartbeatWatchdog,
                               StragglerDetector, plan_elastic_remesh)


def test_straggler_detection():
    d = StragglerDetector(threshold=2.0, warmup_steps=2)
    for i in range(5):
        assert not d.observe(i, 1.0)
    assert d.observe(5, 5.0)          # 5x the EMA
    assert d.events[0][0] == 5
    # straggler does not poison the EMA
    assert d.ema == pytest.approx(1.0, rel=0.01)


def test_watchdog_with_fake_clock():
    t = [0.0]
    wd = HeartbeatWatchdog(timeout_factor=3.0, min_timeout=10.0,
                           clock=lambda: t[0])
    for _ in range(5):
        t[0] += 2.0
        wd.beat()
    assert not wd.poll()
    t[0] += 9.0                        # < min_timeout
    assert not wd.poll()
    t[0] += 5.0                        # now past the 10s floor
    assert wd.poll()


def test_elastic_plan_keeps_model_axis():
    plan = plan_elastic_remesh(available_chips=240, model_axis=16,
                               target_batch=256)
    assert plan.model_axis == 16
    assert plan.data_axis == 15
    assert plan.global_batch % (plan.data_axis * plan.pod_axis) == 0
    assert plan.dropped_chips == 240 - 15 * 16


def test_elastic_plan_insufficient_chips():
    with pytest.raises(ValueError):
        plan_elastic_remesh(available_chips=8, model_axis=16,
                            target_batch=256)


def test_fault_injector_fires_once():
    inj = FaultInjector(fail_at_steps=(3,))
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError):
        inj.maybe_fail(3)
    inj.maybe_fail(3)                  # second pass (post-restart) proceeds
