"""Shared benchmark utilities.

Two measurement modes per the paper's protocol:
  * device model — the TPUCostModelObjective timing (offline target);
  * host wall-clock — jitted XLA-CPU execution of the real kernels,
    median over repeats (genuine empirical numbers on this machine).

Throughput metrics follow the paper: tridiagonal MRows/s = N*b/t*1e-6;
scan MData/s; FFT GFlops/s = 5*N*log2(N)*b/t*1e-9. Batch = 2^26/N
("TOTAL_ELEMS") unless host memory forces a smaller scaled batch, in which
case the scale factor is reported.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict

from repro.core import (AnalyticalTuner, CachedObjective,
                        TPUCostModelObjective, Workload, build_space)
from repro.tuning import get_strategy

HOST_ELEMS = 2 ** 20        # host-sized "2^26" stand-in (CPU wall-clock)
NOISE = 0.02                # cost-model jitter ~ the paper's run-to-run 2%


def median_time(thunk: Callable[[], None], reps: int = 5,
                warmup: int = 2) -> float:
    for _ in range(warmup):
        thunk()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        thunk()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def tune_all_methods(wl: Workload, seed: int = 0) -> Dict[str, Dict]:
    """Run exhaustive + analytical + BO on the device model via the
    repro.tuning strategy registry; returns per-method
    {config, time_s, evals, efficiency}."""
    space = build_space(wl)
    obj = CachedObjective(TPUCostModelObjective(noise=NOISE))
    ex = get_strategy("exhaustive")(space, obj, seed=seed)
    ana_cfg = AnalyticalTuner().suggest(space)
    t_ana = obj(space, ana_cfg).time_s
    bo = get_strategy("bayesian")(
        space, CachedObjective(TPUCostModelObjective(noise=NOISE)), seed=seed)
    return {
        "exhaustive": {"config": ex.best_config, "time_s": ex.best_time,
                       "evals": ex.evaluations, "efficiency": 1.0},
        "analytical": {"config": ana_cfg, "time_s": t_ana, "evals": 0,
                       "efficiency": min(ex.best_time / t_ana, 1.0)},
        "bayesian": {"config": bo.best_config, "time_s": bo.best_time,
                     "evals": bo.evaluations,
                     "efficiency": min(ex.best_time / bo.best_time, 1.0)},
    }


def mrows_per_s(n: int, batch: int, t: float) -> float:
    return n * batch * 1e-6 / t


def mdata_per_s(n: int, batch: int, t: float) -> float:
    return n * batch * 1e-6 / t


def gflops_fft(n: int, batch: int, t: float) -> float:
    return 5.0 * n * math.log2(n) * batch * 1e-9 / t
