"""Model configuration schema + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    arch: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    # transformer backbone
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 0
    activation: str = "swiglu"        # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # attention
    attn_window: Optional[int] = None     # local sliding window (recurrentgemma)
    sub_quadratic: bool = False           # supports 500k-token decode
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024            # GShard routing group S
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # hybrid (recurrentgemma): layer pattern period, e.g. ("rec","rec","attn")
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_len: int = 1500                   # precomputed frame embeddings (stub)
    # vlm
    cross_attn_every: int = 0             # insert cross-attn each k-th layer
    vision_len: int = 1601                # precomputed patch embeddings (stub)
    # numerics / execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                   # none | full
    use_pallas: bool = False              # flip on for real-TPU deployments
    activation_strategy: str = "sp"       # sp | tp (residual-stream sharding;
    #                                       sp shrinks per-layer remat saves
    #                                       by the model-axis size)
    logits_softcap: float = 0.0
    # distribution hints (set by the launcher; 0/() = no explicit
    # constraints, e.g. host smoke tests without a mesh context)
    model_axis_size: int = 0
    batch_axes: Tuple[str, ...] = ()
    batch_shards: int = 0                 # product of batch-axis sizes
    pure_dp: bool = False                 # replicate params; batch over the
    #                                       whole mesh (small-model mapping:
    #                                       TP all-reduces vanish)

    @property
    def is_enc_dec(self) -> bool:
        return self.family == "audio"

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/topology, tiny dimensions."""
        period = max(len(self.block_pattern), 1)
        n_layers = max(2 * period, 2) if self.n_layers else 0
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, n_layers) or self.n_layers,
            d_model=min(self.d_model, 64) if self.d_model else 0,
            n_heads=min(self.n_heads, 4) or self.n_heads,
            n_kv_heads=max(min(self.n_kv_heads, 2), 1) if self.n_kv_heads else 0,
            head_dim=min(self.head_dim, 16) or self.head_dim,
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            d_ff_expert=min(self.d_ff_expert, 64) if self.d_ff_expert else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            capacity_factor=4.0,   # avoid token drops in tiny smoke batches
            #                        (capacity effects are exercised at scale)
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 8),
            lru_width=min(self.lru_width, 64) if self.lru_width else 0,
            n_enc_layers=min(self.n_enc_layers, 2) if self.n_enc_layers else 0,
            n_dec_layers=min(self.n_dec_layers, 2) if self.n_dec_layers else 0,
            enc_len=min(self.enc_len, 16),
            vision_len=min(self.vision_len, 16),
            cross_attn_every=min(self.cross_attn_every, 2) if self.cross_attn_every else 0,
            attn_window=min(self.attn_window, 32) if self.attn_window else None,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_archs() -> Sequence[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # importing the config modules populates the registry
    import importlib
    for mod in ("gemma_2b", "minitron_4b", "qwen15_05b", "granite_34b",
                "whisper_large_v3", "llama32_vision_90b", "qwen2_moe_a27b",
                "qwen3_moe_30b_a3b", "recurrentgemma_9b", "mamba2_130m"):
        importlib.import_module(f"repro.configs.{mod}")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k-token decode requires "
                       "sub-quadratic attention (skip per assignment)")
    return True, ""
