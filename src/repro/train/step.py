"""train_step / serve_step builders (the functions the dry-run lowers).

Loss is vocab-sharding-aware: the label logit is contracted with a fused
one-hot (iota-compare) einsum and logsumexp reduces over the sharded vocab
axis, so the full (B, L, V) logits are never all-gathered — with V on
"model" this costs one small (B, L) all-reduce instead of a 200 GB gather.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import (adamw, adafactor, clip_by_global_norm, warmup_cosine)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    weight_decay: float = 0.1
    aux_weight: float = 0.01          # MoE load-balance loss weight
    z_weight: float = 1e-4            # z-loss (logit norm regularizer)
    micro_steps: int = 1              # gradient accumulation
    optimizer: str = "adamw"          # adamw | adafactor
    grad_compression: str = "none"    # none | int8_ef


def make_optimizer(hp: TrainHParams):
    lr = warmup_cosine(hp.peak_lr, hp.warmup_steps, hp.total_steps)
    if hp.optimizer == "adamw":
        return adamw(lr, weight_decay=hp.weight_decay)
    if hp.optimizer == "adafactor":
        return adafactor(lr, weight_decay=hp.weight_decay)
    raise ValueError(hp.optimizer)


def cross_entropy(logits: jax.Array, targets: jax.Array, mask: jax.Array,
                  z_weight: float = 0.0) -> Tuple[jax.Array, Dict]:
    """logits fp32 (B, L, V) [vocab possibly sharded], targets (B, L)."""
    lse = jax.nn.logsumexp(logits, axis=-1)                        # (B, L)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
              == targets[..., None])
    label_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - label_logit
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    if z_weight:
        loss = loss + z_weight * jnp.sum((lse * lse) * mask) / denom
    # accuracy without argmax: argmax over the model-sharded vocab axis
    # forces an all-gather of the full logits; max+compare partitions cleanly
    max_logit = jnp.max(logits, axis=-1)
    acc = jnp.sum((label_logit >= max_logit) * mask) / denom
    return loss, {"nll": jnp.sum(nll * mask) / denom, "accuracy": acc,
                  "tokens": denom}


def make_loss_fn(model: Model, hp: TrainHParams):
    def loss_fn(params, batch):
        memory = batch.get("memory")
        logits, aux = model.forward(params, batch["tokens"], memory=memory)
        loss, metrics = cross_entropy(logits, batch["targets"],
                                      batch["mask"], hp.z_weight)
        total = loss + hp.aux_weight * aux
        metrics = dict(metrics, loss=loss, aux=aux)
        return total, metrics

    return loss_fn


def init_train_state(model: Model, hp: TrainHParams, key) -> Dict:
    params = model.init(key)
    opt_init, _ = make_optimizer(hp)
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if hp.grad_compression == "int8_ef":
        from repro.optim.compression import init_error
        state["ef_err"] = init_error(params)
    return state


def make_train_step(model: Model, hp: TrainHParams) -> Callable:
    loss_fn = make_loss_fn(model, hp)
    _, opt_update = make_optimizer(hp)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]

        if hp.micro_steps > 1:
            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            mb = jax.tree.map(
                lambda x: x.reshape((hp.micro_steps,
                                     x.shape[0] // hp.micro_steps)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {k: jnp.zeros((), jnp.float32) for k in
                  ("nll", "accuracy", "tokens", "loss", "aux")}
            (grads, metrics), _ = jax.lax.scan(micro, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / hp.micro_steps, grads)
            metrics = jax.tree.map(lambda m: m / hp.micro_steps, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)

        if hp.grad_compression == "int8_ef":
            from repro.optim.compression import ef_roundtrip
            grads, new_err = ef_roundtrip(grads, state["ef_err"])

        grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
        new_params, new_opt = opt_update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if hp.grad_compression == "int8_ef":
            new_state["ef_err"] = new_err
        metrics = dict(metrics, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch: Dict) -> jax.Array:
        logits, _ = model.forward(params, batch["tokens"],
                                  memory=batch.get("memory"))
        return logits[:, -1]

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, token, cache, pos, memory=None):
        logits, new_cache = model.decode_step(params, token, cache, pos,
                                              memory=memory)
        return logits[:, 0], new_cache

    return decode_step
