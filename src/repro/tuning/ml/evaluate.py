"""Model-quality report: the paper's Table-style evaluation for strategy="ml".

For each held-out workload the report compares what the deployed decision
rule (``MLStrategy.choose`` — learned ranking, analytical defer, fallback
ladder and all) picks against the exhaustive optimum on the offline
objective:

  * **top-1 match** — the chosen config achieves the optimum's time within
    a tie tolerance (exact config equality is too strict: spaces contain
    distinct configs with identical modeled times);
  * **slowdown** — time(chosen) / time(true best), >= 1.0;
  * **ml_rate** — the fraction of workloads answered by the learned rungs
    ("ml" / "ml-defer-analytical") rather than a fallback.  Without this,
    a regression that drives every prediction into low-confidence would
    sail through the accuracy floors on the analytical fallback's answers;
  * **rank_corr** — Spearman correlation between the forest's predicted
    ranking and the true time ranking over each workload's candidates.
    This measures the learned model *itself*: a degenerate forest (e.g. a
    featurization bug flattening predictions) makes every workload defer
    to the analytical suggestion — ml_rate stays 1.0 and top-1 stays at
    the expert's level — but its rank correlation collapses toward 0.

The aggregate floors (``min_top1``, ``max_mean_slowdown``, ``min_ml_rate``,
``min_rank_corr``) are what CI's ``train-eval-model`` job pins,
regression-gating the learned strategy like code.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.objective import CostModelObjective, Objective
from repro.core.space import Workload, build_space
from repro.tuning.ml.dataset import suite_workloads, sweep_workload
from repro.tuning.ml.forest import ModelBundle
from repro.tuning.ml.strategy import MLStrategy

TIE_TOL = 1e-3     # relative time slack under which two configs count equal

ML_RUNGS = ("ml", "ml-defer-analytical")


def _rank(v: np.ndarray) -> np.ndarray:
    """Average ranks, scipy-style: exact ties share their mean rank, so the
    correlation cannot be deflated (or inflated) by whatever enumeration
    order tied-time candidates happen to appear in."""
    order = np.argsort(v, kind="stable")
    ranks = np.empty(len(v))
    ranks[order] = np.arange(len(v))
    _, inv = np.unique(v, return_inverse=True)
    sums = np.bincount(inv, weights=ranks)
    counts = np.bincount(inv)
    return (sums / counts)[inv]


def spearman(pred: np.ndarray, truth: np.ndarray) -> float:
    """Rank correlation of the forest's ordering vs the true ordering."""
    if len(pred) < 2:
        return 1.0
    rp, rt = _rank(np.asarray(pred)), _rank(np.asarray(truth))
    if rp.std() == 0 or rt.std() == 0:
        return 0.0
    return float(np.corrcoef(rp, rt)[0, 1])


def evaluate_model(bundle: ModelBundle,
                   workloads: Optional[Iterable[Workload]] = None,
                   objective: Optional[Objective] = None) -> Dict:
    """Per-workload + aggregate accuracy of the deployed decision rule."""
    workloads = list(workloads) if workloads is not None \
        else suite_workloads("holdout")
    objective = objective or CostModelObjective()
    strategy = MLStrategy(model=bundle)
    rows: List[Dict] = []
    for wl in workloads:
        wl = wl.canonical()
        cfgs, X, times = sweep_workload(wl, objective)
        space = build_space(wl)
        pred = strategy.predict(space, cfgs, X)        # one forest pass
        pick, rung = strategy.choose(space, cfgs, X, pred=pred)
        best = int(np.argmin(times))
        slowdown = float(times[pick] / times[best])
        rows.append({
            "workload": wl.key, "op": wl.op, "n": wl.n,
            "candidates": len(cfgs),
            "rung": rung,
            "chosen_config": dict(cfgs[pick]),
            "best_config": dict(cfgs[best]),
            "slowdown": slowdown,
            "top1": bool(slowdown <= 1.0 + TIE_TOL),
            "rank_corr": spearman(pred[0], times) if pred is not None
            else None,
        })

    report: Dict = {"workloads": rows, "n_scored": len(rows)}
    if rows:
        slowdowns = np.array([r["slowdown"] for r in rows])
        rungs: Dict[str, int] = {}
        for r in rows:
            rungs[r["rung"]] = rungs.get(r["rung"], 0) + 1
        corrs = [r["rank_corr"] for r in rows if r["rank_corr"] is not None]
        report.update({
            "top1_rate": float(np.mean([r["top1"] for r in rows])),
            "mean_slowdown": float(slowdowns.mean()),
            "max_slowdown": float(slowdowns.max()),
            "rungs": rungs,
            "ml_rate": float(np.mean([r["rung"] in ML_RUNGS for r in rows])),
            "mean_rank_corr": float(np.mean(corrs)) if corrs else 0.0,
        })
        per_op: Dict[str, Dict] = {}
        for op in sorted({r["op"] for r in rows}):
            sub = [r for r in rows if r["op"] == op]
            sd = np.array([r["slowdown"] for r in sub])
            per_op[op] = {"n": len(sub),
                          "top1_rate": float(np.mean([r["top1"] for r in sub])),
                          "mean_slowdown": float(sd.mean()),
                          "max_slowdown": float(sd.max())}
        report["per_op"] = per_op
    return report


def check_floors(report: Dict, *, min_top1: Optional[float] = None,
                 max_mean_slowdown: Optional[float] = None,
                 min_ml_rate: Optional[float] = None,
                 min_rank_corr: Optional[float] = None) -> List[str]:
    """Floor violations as human-readable strings (empty == gate passes)."""
    failures = []
    if report.get("n_scored", 0) == 0:
        return ["no workloads were scored"]
    if min_top1 is not None and report["top1_rate"] < min_top1:
        failures.append(f"top-1 match rate {report['top1_rate']:.3f} "
                        f"< floor {min_top1:.3f}")
    if max_mean_slowdown is not None \
            and report["mean_slowdown"] > max_mean_slowdown:
        failures.append(f"mean slowdown {report['mean_slowdown']:.3f}x "
                        f"> ceiling {max_mean_slowdown:.3f}x")
    if min_ml_rate is not None and report["ml_rate"] < min_ml_rate:
        failures.append(f"learned-rung rate {report['ml_rate']:.3f} "
                        f"< floor {min_ml_rate:.3f} "
                        f"(rungs: {report['rungs']})")
    if min_rank_corr is not None \
            and report["mean_rank_corr"] < min_rank_corr:
        failures.append(f"mean rank correlation "
                        f"{report['mean_rank_corr']:.3f} "
                        f"< floor {min_rank_corr:.3f}")
    return failures
