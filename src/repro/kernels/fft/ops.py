"""Tuned FFT entry points: in-VMEM Stockham + four-step large-N driver.

`fft(x)` — x complex (batch, n):
  * n <= max in-VMEM tile: single Stockham kernel launch, radix/rows from
    the TuningDB (paper §V-C small/medium sizes);
  * larger n: Bailey four-step decomposition N = n1*n2 — column FFTs,
    twiddle, row FFTs, transpose — i.e. the paper's §IV-C multi-kernel
    strategy with m kernels; the tile split n1 comes from the tuned
    `tile_n` (analytical rule: the largest resident tile minimizes m).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import Workload, get_config
from repro.core.multikernel import max_resident_tile
from repro.kernels.fft.kernel import fft_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _kernel_fft(x: jax.Array, radix: int, rows: int, inverse: bool,
                interpret: bool) -> jax.Array:
    batch, n = x.shape
    rows = max(min(rows, batch), 1)
    while batch % rows:
        rows //= 2
    re, im = jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)
    yre, yim = fft_pallas(re, im, rows_per_program=max(rows, 1), radix=radix,
                          inverse=inverse, interpret=interpret)
    return (yre + 1j * yim).astype(jnp.complex64)


def fft(x: jax.Array, config: Optional[dict] = None,
        interpret: Optional[bool] = None, inverse: bool = False) -> jax.Array:
    batch, n = x.shape
    interpret = _on_cpu() if interpret is None else interpret
    wl_small = Workload(op="fft", n=n, batch=batch, variant="stockham")
    max_tile = max_resident_tile(wl_small)
    if n <= max_tile:
        cfg = config or get_config(wl_small)
        return _kernel_fft(x, cfg.get("radix", 2),
                           cfg.get("rows_per_program", 4), inverse, interpret)

    # ---- four-step multi-kernel path ----
    cfg = config or get_config(
        Workload(op="large_fft", n=n, batch=batch, variant="stockham"))
    n1 = min(cfg.get("tile_n", 2048), max_tile)
    while n % n1:
        n1 //= 2
    n2 = n // n1
    sign = 1.0 if inverse else -1.0
    v = x.reshape(batch, n2, n1)
    # kernel 1: length-n2 FFTs down the columns (batch*n1 problems)
    vc = jnp.transpose(v, (0, 2, 1)).reshape(batch * n1, n2)
    if n2 <= max_tile:
        vc = _kernel_fft(vc, cfg.get("radix", 2),
                         cfg.get("rows_per_program", 4), inverse, interpret)
    else:  # recurse (m = 3 kernels, paper: N >= 2^19)
        vc = fft(vc, interpret=interpret, inverse=inverse)
    v = jnp.transpose(vc.reshape(batch, n1, n2), (0, 2, 1))
    # twiddle
    k2 = jnp.arange(n2).reshape(1, n2, 1)
    k1 = jnp.arange(n1).reshape(1, 1, n1)
    v = v * jnp.exp(sign * 2j * jnp.pi * (k1 * k2) / n).astype(jnp.complex64)
    # kernel 2: length-n1 FFTs along rows
    vr = v.reshape(batch * n2, n1)
    vr = _kernel_fft(vr, cfg.get("radix", 2), cfg.get("rows_per_program", 4),
                     inverse, interpret)
    v = vr.reshape(batch, n2, n1)
    # transpose for self-sorting output
    return jnp.transpose(v, (0, 2, 1)).reshape(batch, n)


def ifft(x: jax.Array, config: Optional[dict] = None,
         interpret: Optional[bool] = None) -> jax.Array:
    return fft(x, config=config, interpret=interpret, inverse=True)
