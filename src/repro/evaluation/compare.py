"""Methodology comparison against the exhaustive optimum (paper Table II).

For every workload the exhaustive sweep supplies the ground-truth optimum;
each methodology (analytical / ml / online / bayesian / random / ...) is
then scored on the SAME cached objective, so every reported time is a time
the sweep actually measured.  That construction makes the report a bug detector:
performance efficiency is ``best_time / achieved_time`` and can only
exceed 1.0 — "a methodology beat exhaustive search" — if the sweep, the
cache, or a strategy mishandled the objective.  ``check_report`` turns any
such violation (equivalently Phi > 1) into a CI failure.

Emitted metrics per (op, methodology) and overall:

  * **Phi** — the harmonic-mean performance-portability metric
    (``repro.core.metrics``), computed raw (no clamping) so violations
    surface;
  * **mean/max slowdown** — achieved time / optimum;
  * **evaluation counts** — what each methodology paid for its answer
    (the paper's Fig-4 axis).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Sequence

from repro.core.exhaustive import ExhaustiveSearch
from repro.core.objective import CachedObjective, Objective, TPUCostModelObjective
from repro.core.space import Workload, build_space
from repro.tuning.session import get_strategy

DEFAULT_METHODS = ("analytical", "ml", "online", "bayesian", "random")

# efficiencies this far above 1.0 are fp-noise, beyond it a violation
EFFICIENCY_EPS = 1e-9


def _phi_raw(efficiencies: Sequence[float]) -> float:
    """Harmonic mean WITHOUT the (0, 1] range check of metrics.phi — a
    Phi > 1 here is exactly the signal check_report exists to catch."""
    return len(efficiencies) / sum(1.0 / max(e, 1e-12) for e in efficiencies)


def compare_methods(workloads: Iterable[Workload],
                    methods: Sequence[str] = DEFAULT_METHODS,
                    objective_factory: Optional[Callable[[], Objective]] = None,
                    *, seed: int = 0, max_evals: int = 20,
                    journal_dir: Optional[str] = None) -> Dict:
    """Run every methodology against the exhaustive optimum.

    One ``CachedObjective`` per workload is shared by the sweep and every
    strategy, so all methods are scored on identical measurements (and the
    non-exhaustive strategies' repeat visits are cache hits, not new
    evaluations — their ``evaluations`` field still reports what each
    method would have paid standalone).
    """
    rows: List[Dict] = []
    for wl in workloads:
        wl = wl.canonical()
        space = build_space(wl)
        obj = CachedObjective(objective_factory() if objective_factory
                              else TPUCostModelObjective())
        ex = ExhaustiveSearch(journal_dir=journal_dir).tune(space, obj)
        # journal-resumed configs never went through `obj` — seed the shared
        # cache with the sweep's times so every strategy reads the exact
        # measurements the optimum came from (re-measuring on a drifted
        # host would let a method "beat" exhaustive and trip the Phi gate)
        obj.seed(space, ex.history)
        row = {"workload": wl.key, "op": wl.op, "n": wl.n,
               "space_size": len(ex.history),
               "best_time_s": ex.best_time,
               "exhaustive_evaluations": ex.evaluations,
               "methods": {}}
        for name in methods:
            res = get_strategy(name)(space, obj, seed=seed,
                                     max_evals=max_evals)
            eff = ex.best_time / res.best_time
            row["methods"][name] = {
                "time_s": res.best_time,
                "slowdown": res.best_time / ex.best_time,
                "efficiency": eff,
                "evaluations": res.evaluations,
                "stopped_by": res.stopped_by,
                "config": dict(res.best_config),
            }
        rows.append(row)

    report = {"methods": list(methods), "workloads": rows,
              "per_op": {}, "overall": {}, "violations": []}

    ops = sorted({r["op"] for r in rows})
    for name in methods:
        for op in ops:
            sub = [r for r in rows if r["op"] == op]
            effs = [r["methods"][name]["efficiency"] for r in sub]
            slows = [r["methods"][name]["slowdown"] for r in sub]
            report["per_op"].setdefault(op, {})[name] = {
                "phi": _phi_raw(effs),
                "mean_slowdown": sum(slows) / len(slows),
                "mean_evaluations": (sum(r["methods"][name]["evaluations"]
                                         for r in sub) / len(sub)),
                "n": len(sub),
            }
        effs = [r["methods"][name]["efficiency"] for r in rows]
        slows = [r["methods"][name]["slowdown"] for r in rows]
        report["overall"][name] = {
            "phi": _phi_raw(effs),
            "mean_slowdown": sum(slows) / len(slows),
            "max_slowdown": max(slows),
            "total_evaluations": sum(r["methods"][name]["evaluations"]
                                     for r in rows),
            "n": len(rows),
        }
        for r in rows:
            if r["methods"][name]["efficiency"] > 1.0 + EFFICIENCY_EPS:
                report["violations"].append(
                    f"{name} beat exhaustive on {r['workload']}: "
                    f"efficiency={r['methods'][name]['efficiency']:.6f}")
    report["exhaustive_total_evaluations"] = sum(
        r["exhaustive_evaluations"] for r in rows)
    return report


def check_report(report: Dict) -> List[str]:
    """Failure strings; empty when the report is sane.

    Exhaustive search being beaten (efficiency or Phi above 1) is never a
    better methodology — it is a correctness bug in the sweep/objective
    stack, which is why CI fails on it.
    """
    failures = list(report.get("violations", ()))
    for name, agg in report.get("overall", {}).items():
        if agg["phi"] > 1.0 + EFFICIENCY_EPS:
            failures.append(f"overall Phi({name})={agg['phi']:.6f} > 1: "
                            f"exhaustive search was beaten")
    return failures


def format_report(report: Dict) -> str:
    """Human-readable per-op + overall table (the Table-II layout)."""
    lines = []
    header = f"{'op':<10} {'method':<11} {'Phi':>6} {'mean_slow':>9} " \
             f"{'mean_evals':>10}"
    lines.append(header)
    for op, per in sorted(report["per_op"].items()):
        for name in report["methods"]:
            agg = per[name]
            lines.append(f"{op:<10} {name:<11} {agg['phi']:6.3f} "
                         f"{agg['mean_slowdown']:9.3f} "
                         f"{agg['mean_evaluations']:10.1f}")
    lines.append("-" * len(header))
    for name in report["methods"]:
        agg = report["overall"][name]
        lines.append(f"{'OVERALL':<10} {name:<11} {agg['phi']:6.3f} "
                     f"{agg['mean_slowdown']:9.3f} "
                     f"{agg['total_evaluations']:10d}")
    return "\n".join(lines)
