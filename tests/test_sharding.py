"""Sharding-rules engine against an abstract production mesh."""
import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.base import get_arch
from repro.distributed.sharding import (ShardingDecisions, param_specs,
                                        spec_for_leaf)
from repro.models.model import build_model

try:  # newer jax: AbstractMesh(axis_sizes, axis_names)
    MESH = AbstractMesh((16, 16), ("data", "model"))
except TypeError:  # older jax: AbstractMesh(((name, size), ...))
    MESH = AbstractMesh((("data", 16), ("model", 16)))


def test_attention_weights_2d_sharded():
    spec = spec_for_leaf("blocks/attn/wq/w", (1024, 2048), MESH, False)
    assert spec == P("data", "model")
    spec = spec_for_leaf("blocks/attn/wo/w", (2048, 1024), MESH, False)
    assert spec == P("model", "data")


def test_nondivisible_falls_back_replicated():
    d = ShardingDecisions()
    spec = spec_for_leaf("blocks/attn/wk/w", (1024, 24), MESH, False, d)
    assert spec == P("data", None)     # 24 not divisible by 16
    assert d.fallbacks


def test_embed_vocab_on_model():
    spec = spec_for_leaf("embed/table", (256000, 2048), MESH, False)
    assert spec == P("model", "data")


def test_norm_scales_replicated():
    assert spec_for_leaf("blocks/ln1", (2048,), MESH, False) == P()


def test_moe_experts_on_model():
    spec = spec_for_leaf("blocks/moe/wi", (128, 2048, 768), MESH, False)
    assert spec == P("model", "data", None)


def test_scanned_params_get_leading_none():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_specs(params, MESH)
    wq = specs["blocks"]["attn"]["wq"]["w"]
    assert tuple(wq)[0] is None        # scan group dim unsharded
    assert len(tuple(wq)) == 3
