"""Tuned matmul entry point (TuningDB-driven block shapes)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import Workload, get_config
from repro.kernels.matmul.kernel import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def matmul(a: jax.Array, b: jax.Array, config: Optional[dict] = None,
           interpret: Optional[bool] = None,
           use_pallas: Optional[bool] = None) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    if use_pallas is None:
        use_pallas = (not _on_cpu()) or bool(interpret)
    if not use_pallas:
        return matmul_ref(a, b)
    interpret = _on_cpu() if interpret is None else interpret
    cfg = config or get_config(Workload(op="matmul", n=n, batch=m,
                                        variant="tiled"))
    def fit(block, dim):
        block = min(block, dim)
        while dim % block:
            block //= 2
        return max(block, 1)
    return matmul_pallas(a, b, block_m=fit(cfg.get("block_m", 256), m),
                         block_n=fit(cfg.get("block_n", 256), n),
                         block_k=fit(cfg.get("block_k", 256), k),
                         interpret=interpret)
