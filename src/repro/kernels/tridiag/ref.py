"""Oracles for batched tridiagonal solvers.

System i of a batch: a[i,0]=0 and c[i,n-1]=0 (standard convention);
    a[i,j]*x[i,j-1] + b[i,j]*x[i,j] + c[i,j]*x[i,j+1] = d[i,j]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def thomas_ref(a: jax.Array, b: jax.Array, c: jax.Array, d: jax.Array) -> jax.Array:
    """Sequential Thomas algorithm via lax.scan — the exact ground truth."""

    def fwd(carry, abcd):
        cp_prev, dp_prev = carry
        ai, bi, ci, di = abcd
        denom = bi - ai * cp_prev
        cp = ci / denom
        dp = (di - ai * dp_prev) / denom
        return (cp, dp), (cp, dp)

    aT, bT, cT, dT = (jnp.moveaxis(v, -1, 0) for v in (a, b, c, d))
    zeros = jnp.zeros_like(aT[0])
    _, (cp, dp) = jax.lax.scan(fwd, (zeros, zeros), (aT, bT, cT, dT))

    def bwd(x_next, cpdp):
        cpi, dpi = cpdp
        x = dpi - cpi * x_next
        return x, x

    _, xT = jax.lax.scan(bwd, zeros, (cp, dp), reverse=True)
    return jnp.moveaxis(xT, 0, -1)


def dense_solve_ref(a: jax.Array, b: jax.Array, c: jax.Array, d: jax.Array) -> jax.Array:
    """Builds the dense matrix per system and solves — small-n oracle."""
    n = a.shape[-1]
    mat = (jnp.zeros(a.shape[:-1] + (n, n), a.dtype)
           .at[..., jnp.arange(n), jnp.arange(n)].set(b))
    mat = mat.at[..., jnp.arange(1, n), jnp.arange(n - 1)].set(a[..., 1:])
    mat = mat.at[..., jnp.arange(n - 1), jnp.arange(1, n)].set(c[..., :-1])
    return jnp.linalg.solve(mat, d[..., None])[..., 0]


def random_system(key, batch: int, n: int, dtype=jnp.float32):
    """Diagonally-dominant random system (well-conditioned for all solvers)."""
    ka, kb, kc, kd = jax.random.split(key, 4)
    a = jax.random.uniform(ka, (batch, n), dtype, 0.1, 1.0)
    c = jax.random.uniform(kc, (batch, n), dtype, 0.1, 1.0)
    a = a.at[:, 0].set(0.0)
    c = c.at[:, -1].set(0.0)
    b = (jnp.abs(a) + jnp.abs(c)
         + jax.random.uniform(kb, (batch, n), dtype, 1.0, 2.0))
    d = jax.random.normal(kd, (batch, n), dtype)
    return a, b, c, d


def residual(a, b, c, d, x):
    """max |A x - d| — solver-independent correctness check."""
    ax = (a * jnp.pad(x, ((0, 0), (1, 0)))[:, :-1]
          + b * x
          + c * jnp.pad(x, ((0, 0), (0, 1)))[:, 1:])
    return jnp.max(jnp.abs(ax - d))
