"""RG-LRU via the tuned linear-recurrence scan kernel.

The gate computation lives in the model layer; this op runs the recurrence
h_t = a_t h_{t-1} + sqrt(1-a_t^2) u_t by flattening (B, L, D) into
(B*D, L) rows for the scan kernel — the direct integration of the paper's
tuned scan into RecurrentGemma.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.scan.ops import linear_recurrence


def rglru(a: jax.Array, u: jax.Array, config: Optional[dict] = None,
          interpret: Optional[bool] = None,
          use_pallas: Optional[bool] = None) -> jax.Array:
    B, L, D = a.shape
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * u
    a_rows = jnp.transpose(a, (0, 2, 1)).reshape(B * D, L)
    b_rows = jnp.transpose(b, (0, 2, 1)).reshape(B * D, L)
    h = linear_recurrence(a_rows, b_rows, config=config, interpret=interpret,
                          use_pallas=use_pallas)
    return jnp.transpose(h.reshape(B, D, L), (0, 2, 1))
